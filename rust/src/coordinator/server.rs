//! The serving front-end: dynamic batcher + plan selection + pipeline
//! execution + metrics. This is the binary's `serve` path and the
//! examples' entry point.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::{GroupSplit, Testbed};
use crate::coordinator::links::LinkDelay;
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::pipeline::{ExecConfig, ForwardStats, Pipeline};
use crate::metrics::Registry;
use crate::runtime::tensor::Tensor;
use crate::sched::Order;
use crate::solver::{Instance, SolverParams};

/// One embedded request: hidden states for a fixed-S prompt (embedding
/// lookup is out of scope for the tiny model; requests arrive as
/// `[S, M]` activations).
#[derive(Debug, Clone)]
pub struct EmbeddedRequest {
    pub id: u64,
    pub hidden: Tensor, // [S, M]
}

impl EmbeddedRequest {
    /// Deterministic synthetic request.
    pub fn synthetic(id: u64, s: usize, m: usize) -> Self {
        let data: Vec<f32> = (0..s * m)
            .map(|i| {
                let x = (i as u64).wrapping_mul(2654435761).wrapping_add(id * 97);
                ((x % 199) as f32 - 99.0) * 0.005
            })
            .collect();
        Self { id, hidden: Tensor::new(vec![s, m], data) }
    }
}

/// Result for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub hidden: Tensor,
    pub latency_s: f64,
}

/// Scheduling policy for batch execution.
#[derive(Debug, Clone, Copy)]
pub enum Policy {
    Naive,
    PpPipe { r1: usize },
    FinDep { r1: usize, r2: usize, order: Order },
    /// Solve per batch with Algorithm 1 against an emulated testbed
    /// (the online-adaptive mode of §5.5).
    Adaptive,
}

/// The DEP server.
pub struct Server {
    pub pipeline: Pipeline,
    pub metrics: Arc<Registry>,
    /// Emulated testbed used by the Adaptive policy's solver (the tiny
    /// model's real CPU constants would make every schedule look alike;
    /// the solver plans against the testbed the deployment targets).
    pub plan_testbed: Testbed,
    pub plan_split: GroupSplit,
    solver_params: SolverParams,
}

impl Server {
    pub fn new(model: ModelHandle, eg: usize, link_delay: Option<LinkDelay>) -> Result<Server> {
        let metrics = Arc::new(Registry::new());
        let plan_testbed = Testbed::a();
        let plan_split = GroupSplit::new(1, eg);
        let pipeline = Pipeline::new(model, eg, link_delay)?;
        Ok(Server {
            pipeline,
            metrics,
            plan_testbed,
            plan_split,
            solver_params: SolverParams { ma_cap: 4, r1_cap: 4, r2_cap: 8 },
        })
    }

    /// Largest attention bucket (preferred m_a).
    fn max_ma(&self) -> usize {
        self.pipeline
            .model()
            .artifacts
            .manifest
            .ma_buckets
            .iter()
            .copied()
            .max()
            .unwrap_or(1)
    }

    /// Choose (m_a, r1, ExecConfig) for an Adaptive batch of `n`
    /// requests: among (bucket m_a, r1 ≤ cap) pairs with minimal padding
    /// `r1·m_a − n`, pick the one the solver scores best against the
    /// emulated target testbed (the §5.5 online mode; the per-batch
    /// re-solve is sub-millisecond here, well under the paper's <1 s).
    fn plan_adaptive(&self, n: usize) -> (usize, usize, ExecConfig) {
        let inst = Instance::new(
            self.pipeline.model().model.clone(),
            self.plan_testbed.clone(),
            self.plan_split,
            self.pipeline.model().seq_len,
        );
        let buckets = &self.pipeline.model().artifacts.manifest.ma_buckets;
        let mut candidates: Vec<(usize, usize)> = Vec::new();
        for &m_a in buckets {
            for r1 in 1..=self.solver_params.r1_cap {
                if r1 * m_a >= n {
                    candidates.push((m_a, r1));
                    break; // larger r1 only adds padding for this m_a
                }
            }
        }
        if candidates.is_empty() {
            // Batch exceeds the largest capacity: take the max and let
            // serve_batch split the overflow into a second call upstream.
            candidates.push((self.max_ma(), self.solver_params.r1_cap));
        }
        let min_pad =
            candidates.iter().map(|(m_a, r1)| r1 * m_a - n.min(r1 * m_a)).min().unwrap();
        let mut best: Option<(usize, usize, ExecConfig, f64)> = None;
        for (m_a, r1) in candidates {
            if r1 * m_a - n.min(r1 * m_a) > min_pad {
                continue;
            }
            let (cfg, _, tput) = crate::solver::bruteforce::best_for_fixed_ma_r1(
                &inst,
                m_a,
                r1,
                self.solver_params.r2_cap,
            );
            if best.as_ref().map_or(true, |b| tput > b.3) {
                best = Some((
                    m_a,
                    r1,
                    ExecConfig { r1, r2: cfg.r2, order: cfg.order, fuse_shared: false },
                    tput,
                ));
            }
        }
        let (m_a, r1, cfg, _) = best.expect("candidate set non-empty");
        (m_a, r1, cfg)
    }

    /// Pad a request list up to `r1·m_a` samples. Returns (batch tensor,
    /// total batch size).
    fn build_batch(&self, reqs: &[EmbeddedRequest], m_a: usize, r1: usize) -> (Tensor, usize) {
        let s = self.pipeline.model().seq_len;
        let m = self.pipeline.model().model.embed;
        let b_total = r1 * m_a;
        let mut data = Vec::with_capacity(b_total * s * m);
        for r in reqs.iter().take(b_total) {
            data.extend_from_slice(&r.hidden.data);
        }
        for _ in reqs.len().min(b_total)..b_total {
            data.extend(std::iter::repeat(0.0).take(s * m));
        }
        (Tensor::new(vec![b_total, s, m], data), b_total)
    }

    /// Smallest m_a bucket such that `r1·m_a` covers the request count
    /// (fixed-policy path).
    fn fit_ma(&self, n: usize, r1: usize) -> usize {
        let buckets = &self.pipeline.model().artifacts.manifest.ma_buckets;
        buckets
            .iter()
            .copied()
            .filter(|&b| r1 * b >= n)
            .min()
            .unwrap_or_else(|| self.max_ma())
    }

    /// Serve one batch of requests under a policy; returns responses
    /// (padding samples dropped) and the pipeline stats.
    pub fn serve_batch(
        &self,
        reqs: &[EmbeddedRequest],
        policy: Policy,
    ) -> Result<(Vec<Response>, ForwardStats)> {
        anyhow::ensure!(!reqs.is_empty(), "empty batch");
        let t0 = Instant::now();
        let (m_a, r1, cfg) = match policy {
            Policy::Naive => {
                let m_a = self.fit_ma(reqs.len(), 1);
                (m_a, 1, ExecConfig::naive())
            }
            Policy::PpPipe { r1 } => (self.fit_ma(reqs.len(), r1), r1, ExecConfig::pppipe(r1)),
            Policy::FinDep { r1, r2, order } => {
                (self.fit_ma(reqs.len(), r1), r1, ExecConfig::findep(r1, r2, order))
            }
            Policy::Adaptive => self.plan_adaptive(reqs.len()),
        };
        let (batch, b_total) = self.build_batch(reqs, m_a, r1);
        anyhow::ensure!(
            b_total >= reqs.len(),
            "batch of {} exceeds serving capacity {b_total}; split upstream",
            reqs.len()
        );
        let (out, stats) = self.pipeline.forward(&batch, cfg)?;
        let latency = t0.elapsed().as_secs_f64();

        let s = self.pipeline.model().seq_len;
        let m = self.pipeline.model().model.embed;
        let responses: Vec<Response> = reqs
            .iter()
            .take(b_total)
            .enumerate()
            .map(|(i, r)| Response {
                id: r.id,
                hidden: Tensor::new(
                    vec![s, m],
                    out.data[i * s * m..(i + 1) * s * m].to_vec(),
                ),
                latency_s: latency,
            })
            .collect();

        self.metrics.inc("batches", 1);
        self.metrics.inc("requests", responses.len() as u64);
        self.metrics.inc("tokens", (responses.len() * s) as u64);
        self.metrics.observe("batch_latency", latency);
        Ok((responses, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts_dir;

    fn server() -> Option<Server> {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let model = ModelHandle::load(&dir, true).unwrap();
        Some(Server::new(model, 2, None).unwrap())
    }

    #[test]
    fn serves_batches_under_all_policies() {
        let Some(srv) = server() else { return };
        let s = srv.pipeline.model().seq_len;
        let m = srv.pipeline.model().model.embed;
        let reqs: Vec<EmbeddedRequest> =
            (0..4).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let mut outputs = Vec::new();
        for policy in [
            Policy::Naive,
            Policy::PpPipe { r1: 2 },
            Policy::FinDep { r1: 2, r2: 2, order: Order::Asas },
            Policy::Adaptive,
        ] {
            let (resp, stats) = srv.serve_batch(&reqs, policy).unwrap();
            assert_eq!(resp.len(), 4);
            assert!(stats.total > 0.0);
            outputs.push(resp);
        }
        // All policies produce identical numerics per request.
        for other in &outputs[1..] {
            for (a, b) in outputs[0].iter().zip(other) {
                assert_eq!(a.id, b.id);
                assert!(a.hidden.max_abs_diff(&b.hidden) < 1e-4);
            }
        }
        assert_eq!(srv.metrics.counter("requests"), 16);
    }

    #[test]
    fn padding_does_not_leak_into_responses() {
        let Some(srv) = server() else { return };
        let s = srv.pipeline.model().seq_len;
        let m = srv.pipeline.model().model.embed;
        // 3 requests with r1=2 -> padded to 4; the 3 real responses must
        // match a 4-request run's first three.
        let reqs3: Vec<EmbeddedRequest> =
            (0..3).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let reqs4: Vec<EmbeddedRequest> =
            (0..4).map(|i| EmbeddedRequest::synthetic(i, s, m)).collect();
        let (r3, _) = srv.serve_batch(&reqs3, Policy::PpPipe { r1: 2 }).unwrap();
        let (r4, _) = srv.serve_batch(&reqs4, Policy::PpPipe { r1: 2 }).unwrap();
        assert_eq!(r3.len(), 3);
        for (a, b) in r3.iter().zip(&r4) {
            assert!(a.hidden.max_abs_diff(&b.hidden) < 1e-5);
        }
    }
}
