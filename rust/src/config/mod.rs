//! Model and cluster configuration types.

pub mod cluster;
pub mod model;

pub use cluster::{GroupSplit, Testbed};
pub use model::{AttentionKind, ModelConfig, Phase};
