//! Decode-phase serving tests: KV-growth accounting, phase-keyed plan
//! cache isolation, per-phase solver behaviour, and (artifact-gated)
//! mixed prefill/decode batch serving with FIFO fairness through the
//! continuous batcher.

use std::time::Duration;

use findep::config::{GroupSplit, ModelConfig, Phase, Testbed};
use findep::coordinator::batcher::{Batcher, BatcherConfig};
use findep::coordinator::moe::ModelHandle;
use findep::coordinator::server::{EmbeddedRequest, Policy, Server};
use findep::runtime::artifacts_dir;
use findep::solver::{self, Instance, MemoryModel, PlanCache, ShapeKey, SolverParams};
use findep::util::rng::Rng;
use findep::workload::{decode_steps, DecodeWorkload, Request};

fn model() -> ModelConfig {
    ModelConfig::deepseek_v2(8)
}

fn split() -> GroupSplit {
    GroupSplit::new(3, 5)
}

// ---- KV-growth accounting ---------------------------------------------

#[test]
fn decode_memory_reads_kv_and_writes_one() {
    let m = model();
    let tb = Testbed::a();
    // The decode phase at kv_len holds kv_len + 1 KV entries (reads the
    // cache, writes this step's entry) and a one-token activation slab.
    let mm = MemoryModel::for_phase(&m, &tb, split(), 1, Phase::Decode { kv_len: 2048 });
    assert_eq!(
        mm.ag_bytes_per_sample(),
        m.kv_bytes_per_sample(2049) + 2 * m.embed * m.bytes_per_elem
    );
    // Walking a request's decode steps grows the resident KV by exactly
    // one entry per generated token.
    let mut req = Request::prefill(0, 2048, 0.0);
    req.output_len = 8;
    let steps = decode_steps(&req);
    assert_eq!(steps.len(), 9);
    let residents: Vec<usize> = steps[1..].iter().map(|s| s.kv_resident()).collect();
    assert_eq!(residents, (2049..=2056).collect::<Vec<_>>());
    // ...and the memory model tracks it monotonically.
    let samples_at = |kv: usize| {
        MemoryModel::for_phase(&m, &tb, split(), 1, Phase::Decode { kv_len: kv })
            .max_samples_per_ag_gpu()
    };
    assert!(samples_at(2056) <= samples_at(2049));
    assert!(samples_at(32768) < samples_at(2049));
}

#[test]
fn decode_holds_more_inflight_samples_than_prefill() {
    // Same resident KV, no full-prompt activation slab: the whole point
    // of disaggregated decode serving is the much deeper in-flight
    // sample pool (MegaScale-Infer's steady state).
    let m = model();
    let tb = Testbed::a();
    let pre = MemoryModel::new(&m, &tb, split(), 2048);
    let dec = MemoryModel::for_phase(&m, &tb, split(), 1, Phase::Decode { kv_len: 2047 });
    assert!(dec.max_samples_per_ag_gpu() > pre.max_samples_per_ag_gpu());
}

// ---- per-phase solving ------------------------------------------------

#[test]
fn solver_produces_distinct_phase_plans() {
    let params = SolverParams::default();
    let pre = solver::solve(&Instance::new(model(), Testbed::a(), split(), 2048), &params)
        .expect("prefill feasible");
    let dec = solver::solve(&Instance::decode(model(), Testbed::a(), split(), 2048), &params)
        .expect("decode feasible");
    // Prefill overlaps communication behind fine-grained parts; decode
    // token conservation (m_e < 1) collapses to r2 = 1.
    assert!(pre.config.r2 > 1);
    assert_eq!(dec.config.r2, 1);
    assert_ne!(pre.config, dec.config);
}

// ---- phase-keyed cache isolation --------------------------------------

#[test]
fn plan_cache_isolates_phases() {
    let params = SolverParams::default();
    let cache = PlanCache::new();
    let batch = 8usize;

    // Solve and memoize the prefill shape first.
    let pre_inst = Instance::new(model(), Testbed::a(), split(), 2048);
    let mut solves = 0usize;
    let pre = cache
        .get_or_solve(ShapeKey::prefill(1, batch), || {
            solves += 1;
            solver::solve_online(&pre_inst, batch, &params)
        })
        .expect("prefill feasible");

    // The decode shape with *numerically identical* (seq, batch) must
    // miss — the phase is part of the key — and yield the decode plan.
    let dec_inst = Instance::decode(model(), Testbed::a(), split(), 2048);
    let dec = cache
        .get_or_solve(ShapeKey::decode(1, batch), || {
            solves += 1;
            solver::solve_online(&dec_inst, batch, &params)
        })
        .expect("decode feasible");
    assert_eq!(solves, 2, "decode must not alias the prefill entry");
    assert_eq!(cache.len(), 2);

    // Each phase's hit returns its own plan unchanged.
    let pre_hit =
        cache.get_or_solve(ShapeKey::prefill(1, batch), || panic!("prefill must hit")).unwrap();
    let dec_hit =
        cache.get_or_solve(ShapeKey::decode(1, batch), || panic!("decode must hit")).unwrap();
    assert_eq!(pre.config, pre_hit.config);
    assert_eq!(dec.config, dec_hit.config);
    assert_ne!(pre_hit.config.r2, dec_hit.config.r2, "phases cached each other's plan");

    // KV growth within one power-of-two bucket reuses the entry; a new
    // bucket misses once.
    assert_eq!(ShapeKey::decode(2049, batch), ShapeKey::decode(4096, batch));
    assert_ne!(ShapeKey::decode(2048, batch), ShapeKey::decode(2049, batch));
}

// ---- decode workload shapes -------------------------------------------

#[test]
fn decode_workload_streams_are_plannable() {
    // Every step of every generated request must produce a feasible
    // online solve on the paper instance (the serving loop's invariant).
    let w = DecodeWorkload::paper_scenario(3072);
    let mut rng = Rng::new(11);
    let reqs = w.generate(4, &mut rng);
    let params = SolverParams::default();
    for req in &reqs {
        // Probe the prefill pass and a sample of decode steps (first,
        // middle, last) rather than all ~256 for test speed.
        let steps = decode_steps(req);
        assert_eq!(steps.len(), 1 + req.output_len);
        for idx in [0, 1, steps.len() / 2, steps.len() - 1] {
            let step = &steps[idx];
            let inst = match step.phase {
                Phase::Prefill => Instance::new(model(), Testbed::a(), split(), step.seq_len),
                Phase::Decode { kv_len } => {
                    Instance::decode(model(), Testbed::a(), split(), kv_len)
                }
            };
            let sol = solver::solve_online(&inst, 4, &params);
            assert!(sol.is_some(), "step {idx} of request {} infeasible", req.id);
        }
    }
}

// ---- artifact-gated: mixed batches through the real coordinator -------

fn skip() -> bool {
    let missing = !artifacts_dir().join("manifest.json").exists();
    if missing {
        eprintln!("skipping: run `make artifacts` first");
    }
    missing
}

#[test]
fn mixed_batch_preserves_request_order_and_numerics() {
    if skip() {
        return;
    }
    let srv = Server::new(ModelHandle::load(&artifacts_dir(), true).unwrap(), 2, None).unwrap();
    let s = srv.pipeline.model().seq_len;
    let m = srv.pipeline.model().model.embed;
    // Interleave prefill and decode requests in one batch.
    let batch: Vec<EmbeddedRequest> = (0..6u64)
        .map(|i| {
            let mut r = EmbeddedRequest::synthetic(i, s, m);
            if i % 2 == 0 {
                r.phase = Phase::Decode { kv_len: s + i as usize };
            }
            r
        })
        .collect();
    let (resp, stats) = srv.serve_batch(&batch, Policy::Adaptive).unwrap();
    assert_eq!(resp.len(), 6);
    assert!(stats.total > 0.0);
    // Responses come back in original request order despite the
    // phase split...
    for (i, r) in resp.iter().enumerate() {
        assert_eq!(r.id, i as u64, "mixed batch reordered responses");
    }
    // ...with numerics identical to serving each request alone (the
    // phase changes scheduling and accounting, never values).
    for (i, r) in resp.iter().enumerate() {
        let (solo, _) = srv.serve_batch(&batch[i..i + 1], Policy::Naive).unwrap();
        let diff = r.hidden.max_abs_diff(&solo[0].hidden);
        assert!(diff < 1e-4, "request {i} drifted by {diff} in the mixed batch");
    }
    // Both phase plans were solved and cached separately.
    assert!(srv.plan_cache().len() >= 2, "expected prefill + decode cached shapes");
    // Token accounting: 3 prefill prompts + 3 decoded tokens... plus
    // the 6 solo naive serves above (all prefill-priced except the
    // decode solos).
    assert_eq!(srv.metrics.counter("decode_tokens"), 3 + 3);
}

#[test]
fn batcher_decode_reentry_completes_fifo() {
    if skip() {
        return;
    }
    let model = ModelHandle::load(&artifacts_dir(), true).unwrap();
    let (s, m) = (model.seq_len, model.model.embed);
    let cfg = BatcherConfig {
        workers: 1,
        max_batch: 4,
        policy: Policy::Adaptive,
        linger: Duration::from_micros(200),
        ..Default::default()
    };
    let batcher = Batcher::new(model, cfg).unwrap();
    let out_len = 3usize;
    for i in 0..8u64 {
        batcher
            .submit(EmbeddedRequest::synthetic_autoregressive(i, s, m, out_len))
            .unwrap();
    }
    let resps = batcher.drain(8, Duration::from_secs(60));
    assert_eq!(resps.len(), 8, "autoregressive requests lost responses");
    // Mixed-batch FIFO fairness: equal-output requests submitted in
    // order finish in order (decode re-entries take priority over
    // later submissions, so nobody leapfrogs a request that entered
    // the decode loop earlier).
    let ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..8).collect::<Vec<_>>(), "responses missing or duplicated");
    assert_eq!(ids, sorted, "equal-output requests completed out of submission order");
    // Exactly output_len decode steps per request ran, each counted as
    // one generated token; latency covers the whole loop.
    assert_eq!(batcher.metrics().counter("decode_steps"), 8 * out_len as u64);
    assert_eq!(batcher.metrics().counter("decode_tokens"), 8 * out_len as u64);
    for r in &resps {
        assert!(r.latency_s > 0.0);
    }
    // Every pass (prefill + each decode step) crossed the queue once.
    assert_eq!(
        batcher.metrics().histogram_count("queue_wait"),
        8 * (1 + out_len) as u64
    );
    // Prefill and decode shapes live side by side in the shared cache.
    assert!(batcher.plan_cache().len() >= 2);
}
