//! Cluster / testbed configurations (Table 2) and the DEP group split.
//!
//! The paper's four testbeds span three hardware regimes: compute-bound
//! with modest interconnect (A: 8×A6000 NVLink-bridged), comm-bound
//! (B: 8×A10, PCIe only), comm-cheap (C: 8×H20, fat NVLink), and
//! multi-node balanced (D: 32×H20, NVLink intra-node + network across
//! nodes). We reproduce those *regimes* with effective-throughput
//! constants; absolute numbers differ from the authors' testbeds, the
//! relative behaviours (who is bottlenecked on what) are what Tables 3-7
//! exercise.

use crate::util::json::{Json, JsonObj};

/// One hardware testbed.
#[derive(Debug, Clone, PartialEq)]
pub struct Testbed {
    pub name: String,
    pub n_gpus: usize,
    /// Device memory per GPU, bytes.
    pub mem_bytes: usize,
    /// Achieved dense-GEMM throughput used for β_gm, FLOP/s.
    pub gemm_flops: f64,
    /// Achieved attention throughput used for β_attn, FLOP/s (attention
    /// is less MXU-friendly than plain GEMM; the paper fits it separately
    /// in Fig. 7a).
    pub attn_flops: f64,
    /// Kernel-launch / dispatch fixed overhead, seconds (α_gm).
    pub alpha_comp_s: f64,
    /// Attention-kernel fixed overhead, seconds (α_attn).
    pub alpha_attn_s: f64,
    /// Per-GPU inter-group link bandwidth, bytes/s.
    pub link_bw: f64,
    /// Network/transfer startup latency, seconds (α_c).
    pub alpha_comm_s: f64,
    /// Achieved device-memory (HBM) streaming bandwidth, bytes/s — the
    /// decode-phase attention regime is bound by KV-cache reads at this
    /// rate rather than by attention FLOPs.
    pub hbm_bw: f64,
    pub nvlink: bool,
    pub multi_node: bool,
}

impl Testbed {
    /// Testbed A — 8× RTX A6000 48 GB, NVLink bridges, PCIe 4.0 host.
    pub fn a() -> Self {
        Self {
            name: "A (8xA6000)".into(),
            n_gpus: 8,
            mem_bytes: 48 * GB,
            gemm_flops: 110e12,
            attn_flops: 80e12,
            alpha_comp_s: 18e-6,
            alpha_attn_s: 25e-6,
            // NVLink bridges only pair GPUs; cross-group NCCL rides the
            // PCIe-4 fabric with contention.
            link_bw: 12e9,
            alpha_comm_s: 30e-6,
            hbm_bw: 768e9, // GDDR6 A6000
            nvlink: true,
            multi_node: false,
        }
    }

    /// Testbed B — 8× A10 24 GB, PCIe 4.0 x16 only (comm-bound regime).
    pub fn b() -> Self {
        Self {
            name: "B (8xA10)".into(),
            n_gpus: 8,
            mem_bytes: 24 * GB,
            gemm_flops: 90e12,
            attn_flops: 60e12,
            alpha_comp_s: 18e-6,
            alpha_attn_s: 25e-6,
            link_bw: 8e9, // PCIe 4.0 shared fabric, no NVLink (comm-bound)
            alpha_comm_s: 40e-6,
            hbm_bw: 600e9, // GDDR6 A10
            nvlink: false,
            multi_node: false,
        }
    }

    /// Testbed C — 8× H20 96 GB, 900 GB/s NVLink (comm-cheap regime).
    pub fn c() -> Self {
        Self {
            name: "C (8xH20)".into(),
            n_gpus: 8,
            mem_bytes: 96 * GB,
            gemm_flops: 130e12,
            attn_flops: 100e12,
            alpha_comp_s: 12e-6,
            alpha_attn_s: 18e-6,
            link_bw: 300e9, // NVSwitch effective per-GPU (comm-cheap)
            alpha_comm_s: 20e-6,
            hbm_bw: 4000e9, // HBM3 H20
            nvlink: true,
            multi_node: false,
        }
    }

    /// Testbed D — 4 nodes × 8 H20 (32 GPUs); inter-group traffic crosses
    /// the node network, so bandwidth sits between B and C (balanced
    /// regime, §5.5 Discussion).
    pub fn d() -> Self {
        Self {
            name: "D (32xH20)".into(),
            n_gpus: 32,
            mem_bytes: 96 * GB,
            gemm_flops: 130e12,
            attn_flops: 100e12,
            alpha_comp_s: 12e-6,
            alpha_attn_s: 18e-6,
            link_bw: 35e9, // 400G-class NICs across nodes (balanced)
            alpha_comm_s: 80e-6,
            hbm_bw: 4000e9, // HBM3 H20
            nvlink: true,
            multi_node: true,
        }
    }

    /// Replace the measured component constants with a calibration
    /// profile's fitted values, keeping `base`'s cluster topology (GPU
    /// count, device memory, link kind, node layout): host probes can
    /// measure throughputs and launch overheads, not how many GPUs the
    /// deployment has. This is the trace-driven counterpart of the
    /// hand-written Table-2 constructors — everything downstream
    /// (stage models, memory model, solver, simulator) is untouched,
    /// so a profile whose constants equal Table-2's reproduces the
    /// hand-constant solve bit for bit.
    pub fn from_profile(
        base: &Testbed,
        profile: &crate::perfmodel::profile::CalibrationProfile,
    ) -> Self {
        Self {
            name: format!("{} [calibrated: {}]", base.name, profile.host),
            gemm_flops: profile.gemm.unit_per_s,
            alpha_comp_s: profile.gemm.alpha_s,
            attn_flops: profile.attn.unit_per_s,
            alpha_attn_s: profile.attn.alpha_s,
            link_bw: profile.comm.unit_per_s,
            alpha_comm_s: profile.comm.alpha_s,
            hbm_bw: profile.hbm.unit_per_s,
            ..base.clone()
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_uppercase().as_str() {
            "A" => Some(Self::a()),
            "B" => Some(Self::b()),
            "C" => Some(Self::c()),
            "D" => Some(Self::d()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::a(), Self::b(), Self::c(), Self::d()]
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", Json::Str(self.name.clone()));
        o.insert("n_gpus", Json::Num(self.n_gpus as f64));
        o.insert("mem_bytes", Json::Num(self.mem_bytes as f64));
        o.insert("gemm_flops", Json::Num(self.gemm_flops));
        o.insert("attn_flops", Json::Num(self.attn_flops));
        o.insert("alpha_comp_s", Json::Num(self.alpha_comp_s));
        o.insert("alpha_attn_s", Json::Num(self.alpha_attn_s));
        o.insert("link_bw", Json::Num(self.link_bw));
        o.insert("alpha_comm_s", Json::Num(self.alpha_comm_s));
        o.insert("hbm_bw", Json::Num(self.hbm_bw));
        o.insert("nvlink", Json::Bool(self.nvlink));
        o.insert("multi_node", Json::Bool(self.multi_node));
        Json::Obj(o)
    }
}

const GB: usize = 1 << 30;

/// One GPU type's per-device constants — the Table-2 columns minus the
/// count. A [`GpuPool`] is `n` devices of one spec behind one NIC
/// class; a [`Cluster`] wires pools into the DEP roles.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Device memory per GPU, bytes.
    pub mem_bytes: usize,
    /// Achieved dense-GEMM throughput, FLOP/s (β_gm = 1/this).
    pub gemm_flops: f64,
    /// Achieved attention throughput, FLOP/s.
    pub attn_flops: f64,
    /// Kernel-launch / dispatch fixed overhead, seconds (α_gm).
    pub alpha_comp_s: f64,
    /// Attention-kernel fixed overhead, seconds (α_attn).
    pub alpha_attn_s: f64,
    /// Achieved device-memory streaming bandwidth, bytes/s (the
    /// decode-attention KV-read bound).
    pub hbm_bw: f64,
}

impl GpuSpec {
    /// The per-device slice of a Table-2 testbed.
    pub fn from_testbed(tb: &Testbed) -> Self {
        Self {
            name: tb.name.clone(),
            mem_bytes: tb.mem_bytes,
            gemm_flops: tb.gemm_flops,
            attn_flops: tb.attn_flops,
            alpha_comp_s: tb.alpha_comp_s,
            alpha_attn_s: tb.alpha_attn_s,
            hbm_bw: tb.hbm_bw,
        }
    }
}

/// A typed pool: `n_gpus` devices of one [`GpuSpec`] behind one
/// NIC/link class toward the other pool.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuPool {
    pub name: String,
    pub n_gpus: usize,
    pub gpu: GpuSpec,
    /// Per-GPU link/NIC bandwidth toward the peer pool, bytes/s.
    pub link_bw: f64,
    /// Transfer startup latency this pool's side contributes, seconds.
    pub alpha_comm_s: f64,
}

/// The cross-pool M2N transfer model: `ag` attention senders fan out
/// to `eg` expert receivers across a bisection of width `min(ag, eg)`
/// links, each running at the *narrower* side's per-link bandwidth
/// (per-link rates match through the switch — a side with fatter NICs
/// cannot push a single link faster than its peer drains it), with a
/// startup latency of the slower side. This generalizes the Testbed's
/// scalar `link_bw`/`alpha_comm_s`: on a single-pool cluster both
/// sides are the same pool and the model collapses to exactly those
/// scalars — `max(α, α) = α`, `min(bw, bw) = bw` — which is what keeps
/// the compat path bit-identical.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct M2nModel {
    /// Transfer startup latency, seconds (α_c).
    pub alpha_s: f64,
    /// Effective per-link bandwidth across the bisection, bytes/s.
    pub bw: f64,
}

/// Identity of a cluster's pool constants: FNV-1a over every pool's
/// per-device and link constants plus the role wiring, mirroring
/// [`crate::perfmodel::profile::ProfileId`]. Part of every plan-cache
/// key so plans solved under different cluster shapes can never alias.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ClusterId(pub u64);

impl ClusterId {
    /// Reserved identity for the legacy single-pool Testbed keyspace
    /// (every key constructor defaults here; [`Cluster::fingerprint`]
    /// never returns it) — the cluster-typed sibling of
    /// [`crate::perfmodel::profile::ProfileId::HAND`].
    pub const SINGLE: ClusterId = ClusterId(0);
}

/// A heterogeneous cluster: typed [`GpuPool`]s wired into the two DEP
/// roles. `attn_pool`/`expert_pool` index into `pools`; a single-pool
/// cluster points both roles at the same pool (shared inventory, the
/// Table-2 compat path), a two-pool cluster sizes each role from its
/// own inventory (MegaScale-Infer-style disaggregation onto different
/// hardware).
#[derive(Debug, Clone, PartialEq)]
pub struct Cluster {
    pub name: String,
    pub pools: Vec<GpuPool>,
    /// Index of the pool serving the attention groups (and the shared
    /// experts replicated on them).
    pub attn_pool: usize,
    /// Index of the pool serving the expert groups.
    pub expert_pool: usize,
    pub nvlink: bool,
    pub multi_node: bool,
}

impl Cluster {
    /// The compat constructor: a Table-2 testbed as a one-pool cluster,
    /// both DEP roles on the same pool. Everything derived from this
    /// cluster — stage models, memory model, plans, throughput — is
    /// bit-identical to the retired direct-Testbed path (pinned by
    /// `tests/cluster_equivalence.rs`).
    pub fn single_pool(tb: &Testbed) -> Self {
        Self {
            name: tb.name.clone(),
            pools: vec![GpuPool {
                name: tb.name.clone(),
                n_gpus: tb.n_gpus,
                gpu: GpuSpec::from_testbed(tb),
                link_bw: tb.link_bw,
                alpha_comm_s: tb.alpha_comm_s,
            }],
            attn_pool: 0,
            expert_pool: 0,
            nvlink: tb.nvlink,
            multi_node: tb.multi_node,
        }
    }

    /// The reference two-pool heterogeneous cluster the
    /// `hetero_cluster` bench gates on: a compute-rich attention pool
    /// (H20-class FLOPs and HBM for the quadratic prefill attention
    /// and the KV-read-bound decode) feeding a bandwidth-rich expert
    /// pool (cheaper GEMM silicon behind fat NICs — expert FFN is a
    /// thin 3-GEMM stack whose tokens must cross the network twice per
    /// layer, so its pool buys links, not FLOPs).
    pub fn reference_hetero() -> Self {
        Self {
            name: "hetero (4 attn H20-class + 12 expert A6000-class)".into(),
            pools: vec![
                GpuPool {
                    name: "attn (compute-rich)".into(),
                    n_gpus: 4,
                    gpu: GpuSpec {
                        name: "H20-class".into(),
                        mem_bytes: 96 * GB,
                        gemm_flops: 130e12,
                        attn_flops: 100e12,
                        alpha_comp_s: 12e-6,
                        alpha_attn_s: 18e-6,
                        hbm_bw: 4000e9,
                    },
                    link_bw: 50e9,
                    alpha_comm_s: 25e-6,
                },
                GpuPool {
                    name: "expert (bandwidth-rich)".into(),
                    n_gpus: 12,
                    gpu: GpuSpec {
                        name: "A6000-class".into(),
                        mem_bytes: 48 * GB,
                        gemm_flops: 110e12,
                        attn_flops: 80e12,
                        alpha_comp_s: 18e-6,
                        alpha_attn_s: 25e-6,
                        hbm_bw: 768e9,
                    },
                    link_bw: 50e9,
                    alpha_comm_s: 25e-6,
                },
            ],
            attn_pool: 0,
            expert_pool: 1,
            nvlink: true,
            multi_node: true,
        }
    }

    /// Cluster lookup: the Table-2 letters as single-pool clusters,
    /// plus the two-pool reference.
    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_lowercase().as_str() {
            "hetero" => Some(Self::reference_hetero()),
            _ => Testbed::by_name(name).map(|tb| Self::single_pool(&tb)),
        }
    }

    pub fn attn(&self) -> &GpuPool {
        &self.pools[self.attn_pool]
    }

    pub fn expert(&self) -> &GpuPool {
        &self.pools[self.expert_pool]
    }

    /// Both roles draw from one shared GPU inventory.
    pub fn is_single_pool(&self) -> bool {
        self.attn_pool == self.expert_pool
    }

    /// Total GPUs across all pools.
    pub fn n_gpus(&self) -> usize {
        self.pools.iter().map(|p| p.n_gpus).sum()
    }

    /// The cross-pool transfer model (see [`M2nModel`]).
    pub fn m2n(&self) -> M2nModel {
        let a = self.attn();
        let e = self.expert();
        M2nModel {
            alpha_s: a.alpha_comm_s.max(e.alpha_comm_s),
            bw: a.link_bw.min(e.link_bw),
        }
    }

    /// The cluster one instance of a `replicas`-way tiling sees: same
    /// per-GPU and link constants, each pool's inventory divided.
    pub fn tile(&self, replicas: usize) -> Self {
        let mut c = self.clone();
        for p in &mut c.pools {
            p.n_gpus /= replicas;
        }
        c
    }

    /// Replace every pool's measured component constants with a
    /// calibration profile's fitted values, keeping the cluster
    /// topology — the cluster-typed counterpart of
    /// [`Testbed::from_profile`], and bit-identical to it through the
    /// single-pool compat path.
    pub fn from_profile(
        base: &Cluster,
        profile: &crate::perfmodel::profile::CalibrationProfile,
    ) -> Self {
        let mut c = base.clone();
        c.name = format!("{} [calibrated: {}]", base.name, profile.host);
        for p in &mut c.pools {
            p.gpu.gemm_flops = profile.gemm.unit_per_s;
            p.gpu.alpha_comp_s = profile.gemm.alpha_s;
            p.gpu.attn_flops = profile.attn.unit_per_s;
            p.gpu.alpha_attn_s = profile.attn.alpha_s;
            p.gpu.hbm_bw = profile.hbm.unit_per_s;
            p.link_bw = profile.comm.unit_per_s;
            p.alpha_comm_s = profile.comm.alpha_s;
        }
        c
    }

    /// FNV-1a fingerprint over every pool's constants and the role
    /// wiring (the same construction as
    /// [`crate::perfmodel::profile::CalibrationProfile::fingerprint`]):
    /// two clusters differing in any pool count, device constant, link
    /// constant, or role assignment get different identities, so their
    /// plans can never alias in the cache. Never returns
    /// [`ClusterId::SINGLE`].
    pub fn fingerprint(&self) -> ClusterId {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |bits: u64| {
            for b in bits.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        mix(self.pools.len() as u64);
        mix(self.attn_pool as u64);
        mix(self.expert_pool as u64);
        for p in &self.pools {
            mix(p.n_gpus as u64);
            mix(p.gpu.mem_bytes as u64);
            mix(p.gpu.gemm_flops.to_bits());
            mix(p.gpu.attn_flops.to_bits());
            mix(p.gpu.alpha_comp_s.to_bits());
            mix(p.gpu.alpha_attn_s.to_bits());
            mix(p.gpu.hbm_bw.to_bits());
            mix(p.link_bw.to_bits());
            mix(p.alpha_comm_s.to_bits());
        }
        ClusterId(if h == 0 { 1 } else { h })
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", Json::Str(self.name.clone()));
        o.insert("attn_pool", Json::Num(self.attn_pool as f64));
        o.insert("expert_pool", Json::Num(self.expert_pool as f64));
        o.insert("nvlink", Json::Bool(self.nvlink));
        o.insert("multi_node", Json::Bool(self.multi_node));
        let pools = self
            .pools
            .iter()
            .map(|p| {
                let mut po = JsonObj::new();
                po.insert("name", Json::Str(p.name.clone()));
                po.insert("n_gpus", Json::Num(p.n_gpus as f64));
                po.insert("gpu", Json::Str(p.gpu.name.clone()));
                po.insert("mem_bytes", Json::Num(p.gpu.mem_bytes as f64));
                po.insert("gemm_flops", Json::Num(p.gpu.gemm_flops));
                po.insert("attn_flops", Json::Num(p.gpu.attn_flops));
                po.insert("alpha_comp_s", Json::Num(p.gpu.alpha_comp_s));
                po.insert("alpha_attn_s", Json::Num(p.gpu.alpha_attn_s));
                po.insert("hbm_bw", Json::Num(p.gpu.hbm_bw));
                po.insert("link_bw", Json::Num(p.link_bw));
                po.insert("alpha_comm_s", Json::Num(p.alpha_comm_s));
                Json::Obj(po)
            })
            .collect();
        o.insert("pools", Json::Arr(pools));
        Json::Obj(o)
    }
}

/// A DEP partition of a testbed into attention group + expert group
/// (`ag + eg <= n_gpus`, both non-empty).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSplit {
    pub ag: usize,
    pub eg: usize,
}

impl GroupSplit {
    pub fn new(ag: usize, eg: usize) -> Self {
        assert!(ag >= 1 && eg >= 1, "both groups must be non-empty");
        Self { ag, eg }
    }

    /// The paper's evaluated splits per testbed/model (§5.3, §5.5).
    pub fn paper_default(testbed: &Testbed, has_shared: bool) -> Self {
        if testbed.n_gpus >= 32 {
            Self::new(8, 24)
        } else if has_shared {
            Self::new(3, 5) // DeepSeek-V2 on 8-GPU testbeds
        } else {
            Self::new(4, 4) // Qwen3-MoE on 8-GPU testbeds
        }
    }

    /// All valid splits of a testbed (used by ablation benches).
    pub fn enumerate(n_gpus: usize) -> Vec<Self> {
        (1..n_gpus).map(|ag| Self::new(ag, n_gpus - ag)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbeds_have_expected_regimes() {
        let (a, b, c, d) = (Testbed::a(), Testbed::b(), Testbed::c(), Testbed::d());
        // B is the comm-bound regime, C the comm-cheap one.
        assert!(b.link_bw < a.link_bw);
        assert!(c.link_bw > 5.0 * a.link_bw);
        // D crosses nodes: cheaper than C's NVLink, more GPUs.
        assert!(d.link_bw < c.link_bw);
        assert_eq!(d.n_gpus, 32);
        assert!(!b.nvlink && a.nvlink && c.nvlink);
        // HBM streaming (the decode-attention bound) dwarfs the
        // inter-group links everywhere, and the H20 testbeds stream KV
        // far faster than the GDDR cards.
        for t in Testbed::all() {
            assert!(t.hbm_bw > 10.0 * t.link_bw, "{}", t.name);
        }
        assert!(c.hbm_bw > 4.0 * a.hbm_bw);
    }

    #[test]
    fn memory_matches_table2() {
        assert_eq!(Testbed::a().mem_bytes, 48 << 30);
        assert_eq!(Testbed::b().mem_bytes, 24 << 30);
        assert_eq!(Testbed::c().mem_bytes, 96 << 30);
    }

    #[test]
    fn from_profile_swaps_constants_keeps_topology() {
        use crate::perfmodel::profile::CalibrationProfile;
        let base = Testbed::b();
        // A Table-2-equivalent profile reproduces the constants bitwise.
        let same = Testbed::from_profile(&base, &CalibrationProfile::from_testbed(&base));
        for (a, b) in [
            (same.gemm_flops, base.gemm_flops),
            (same.attn_flops, base.attn_flops),
            (same.alpha_comp_s, base.alpha_comp_s),
            (same.alpha_attn_s, base.alpha_attn_s),
            (same.link_bw, base.link_bw),
            (same.alpha_comm_s, base.alpha_comm_s),
            (same.hbm_bw, base.hbm_bw),
        ] {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(same.name.contains("calibrated"));
        // A measured profile moves only the measured constants.
        let mut p = CalibrationProfile::from_testbed(&base);
        p.gemm.unit_per_s = 42e12;
        let cal = Testbed::from_profile(&base, &p);
        assert_eq!(cal.gemm_flops, 42e12);
        assert_eq!(cal.n_gpus, base.n_gpus);
        assert_eq!(cal.mem_bytes, base.mem_bytes);
        assert_eq!(cal.nvlink, base.nvlink);
        assert_eq!(cal.multi_node, base.multi_node);
    }

    #[test]
    fn by_name_case_insensitive() {
        assert_eq!(Testbed::by_name("a").unwrap().n_gpus, 8);
        assert_eq!(Testbed::by_name("D").unwrap().n_gpus, 32);
        assert!(Testbed::by_name("x").is_none());
    }

    #[test]
    fn splits() {
        let s = GroupSplit::paper_default(&Testbed::a(), true);
        assert_eq!((s.ag, s.eg), (3, 5));
        let s = GroupSplit::paper_default(&Testbed::c(), false);
        assert_eq!((s.ag, s.eg), (4, 4));
        let s = GroupSplit::paper_default(&Testbed::d(), true);
        assert_eq!((s.ag, s.eg), (8, 24));
        assert_eq!(GroupSplit::enumerate(8).len(), 7);
    }

    #[test]
    #[should_panic]
    fn empty_group_rejected() {
        GroupSplit::new(0, 8);
    }

    #[test]
    fn single_pool_cluster_mirrors_testbed_bitwise() {
        for tb in Testbed::all() {
            let cl = Cluster::single_pool(&tb);
            assert!(cl.is_single_pool());
            assert_eq!(cl.n_gpus(), tb.n_gpus);
            assert_eq!(cl.attn().gpu.mem_bytes, tb.mem_bytes);
            assert_eq!(cl.expert().gpu.mem_bytes, tb.mem_bytes);
            for (a, b) in [
                (cl.attn().gpu.gemm_flops, tb.gemm_flops),
                (cl.attn().gpu.attn_flops, tb.attn_flops),
                (cl.attn().gpu.alpha_comp_s, tb.alpha_comp_s),
                (cl.attn().gpu.alpha_attn_s, tb.alpha_attn_s),
                (cl.attn().gpu.hbm_bw, tb.hbm_bw),
                (cl.expert().gpu.gemm_flops, tb.gemm_flops),
                // The degenerate M2N collapses to the scalar model.
                (cl.m2n().alpha_s, tb.alpha_comm_s),
                (cl.m2n().bw, tb.link_bw),
            ] {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", tb.name);
            }
        }
    }

    #[test]
    fn m2n_takes_slower_alpha_and_narrower_link() {
        let mut cl = Cluster::reference_hetero();
        cl.pools[0].link_bw = 80e9;
        cl.pools[0].alpha_comm_s = 10e-6;
        cl.pools[1].link_bw = 50e9;
        cl.pools[1].alpha_comm_s = 30e-6;
        let m2n = cl.m2n();
        assert_eq!(m2n.bw, 50e9);
        assert_eq!(m2n.alpha_s, 30e-6);
    }

    #[test]
    fn cluster_fingerprints_distinguish_shapes() {
        let mut seen = std::collections::BTreeSet::new();
        for tb in Testbed::all() {
            assert!(seen.insert(Cluster::single_pool(&tb).fingerprint()));
        }
        let hetero = Cluster::reference_hetero();
        assert!(seen.insert(hetero.fingerprint()));
        // Any perturbed constant, count, or role wiring re-keys.
        let mut p = hetero.clone();
        p.pools[1].link_bw *= 2.0;
        assert!(seen.insert(p.fingerprint()));
        let mut p = hetero.clone();
        p.pools[0].n_gpus += 1;
        assert!(seen.insert(p.fingerprint()));
        let mut p = hetero.clone();
        (p.attn_pool, p.expert_pool) = (1, 0);
        assert!(seen.insert(p.fingerprint()));
        // The name is cosmetic and never part of the identity.
        let mut p = hetero.clone();
        p.name = "renamed".into();
        assert_eq!(p.fingerprint(), hetero.fingerprint());
        assert!(!seen.contains(&ClusterId::SINGLE));
    }

    #[test]
    fn cluster_by_name_covers_testbeds_and_hetero() {
        assert!(Cluster::by_name("a").unwrap().is_single_pool());
        assert_eq!(Cluster::by_name("D").unwrap().n_gpus(), 32);
        let h = Cluster::by_name("hetero").unwrap();
        assert!(!h.is_single_pool());
        assert_eq!(h.pools.len(), 2);
        assert!(Cluster::by_name("x").is_none());
    }

    #[test]
    fn tile_divides_every_pool() {
        let h = Cluster::reference_hetero();
        let t = h.tile(2);
        assert_eq!(t.attn().n_gpus, h.attn().n_gpus / 2);
        assert_eq!(t.expert().n_gpus, h.expert().n_gpus / 2);
        let s = Cluster::single_pool(&Testbed::d()).tile(4);
        assert_eq!(s.n_gpus(), 8);
    }

    #[test]
    fn cluster_from_profile_matches_testbed_from_profile() {
        use crate::perfmodel::profile::CalibrationProfile;
        let base = Testbed::b();
        let mut p = CalibrationProfile::from_testbed(&base);
        p.gemm.unit_per_s = 42e12;
        p.comm.alpha_s = 55e-6;
        let tb_cal = Testbed::from_profile(&base, &p);
        let cl_cal = Cluster::from_profile(&Cluster::single_pool(&base), &p);
        assert_eq!(cl_cal.name, tb_cal.name);
        assert_eq!(
            cl_cal.fingerprint(),
            Cluster::single_pool(&tb_cal).fingerprint()
        );
    }
}
