//! Fine-grained DEP schedule plans.
//!
//! A plan materializes one forward pass of the disaggregated pipeline as
//! a task DAG over four exclusive resources (Eq. 5's first five rules)
//! plus the data-dependency rules 6-9:
//!
//! * `Shared(t,i)`  after `Attn(t,i)`
//! * `A2E(t,i,j)`   after `Attn(t,i)`
//! * `Expert(t,i,j)` after `A2E(t,i,j)`
//! * `E2A(t,i,j)`   after `Expert(t,i,j)`
//! * `Attn(t+1,i)`  after all `E2A(t,i,·)` and `Shared(t,i)`
//!
//! The AG issue order distinguishes ASAS from AASS (§4.2); links and EG
//! issue lexicographically. PPPipe is expressed in the same vocabulary by
//! fusing the shared expert into attention and pinning `r2 = 1`
//! (`PlanConfig::pppipe`).
//!
//! ## Storage layout (hot-path contract)
//!
//! Dependency edges live in one flat pool (`Plan::deps(i)` slices it),
//! not in per-task `Vec`s, and [`Plan::build_into`] rewrites an existing
//! [`PlanBuffers`] arena in place. Algorithm 1 evaluates hundreds of
//! `(m_a, order, r2)` candidates per solve; with the arena the whole
//! search performs zero task/dep allocations after the first candidate.
//! [`Plan::build`] is the one-shot convenience wrapper over the same
//! code path, so the two can never drift.

use crate::perfmodel::StageModels;

/// Execution order of attention vs shared-expert segments on the AG
/// (§4.2 "Determine the order of Attention and Shared Expert").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Order {
    /// Attention-Shared alternating: A0 S0 A1 S1 …
    Asas,
    /// Attention-all then Shared-all: A0 A1 … S0 S1 …
    Aass,
}

impl Order {
    pub fn name(self) -> &'static str {
        match self {
            Order::Asas => "ASAS",
            Order::Aass => "AASS",
        }
    }

    pub fn both() -> [Order; 2] {
        [Order::Asas, Order::Aass]
    }
}

/// The four exclusive resources of the DEP pipeline (§3.2: "each
/// operation runs on a dedicated machine").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Resource {
    /// Attention-group GPUs (attention + shared expert).
    AgCompute,
    /// Expert-group GPUs.
    EgCompute,
    /// Attention→Expert link direction.
    A2ELink,
    /// Expert→Attention link direction (full duplex with A2E).
    E2ALink,
}

impl Resource {
    pub const ALL: [Resource; 4] =
        [Resource::AgCompute, Resource::EgCompute, Resource::A2ELink, Resource::E2ALink];

    pub fn index(self) -> usize {
        match self {
            Resource::AgCompute => 0,
            Resource::EgCompute => 1,
            Resource::A2ELink => 2,
            Resource::E2ALink => 3,
        }
    }

    pub fn is_compute(self) -> bool {
        matches!(self, Resource::AgCompute | Resource::EgCompute)
    }

    pub fn name(self) -> &'static str {
        match self {
            Resource::AgCompute => "AG",
            Resource::EgCompute => "EG",
            Resource::A2ELink => "A2E",
            Resource::E2ALink => "E2A",
        }
    }
}

/// Task flavours of the fine-grained pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Attention,
    SharedExpert,
    A2E,
    Expert,
    E2A,
}

impl TaskKind {
    pub fn resource(self) -> Resource {
        match self {
            TaskKind::Attention | TaskKind::SharedExpert => Resource::AgCompute,
            TaskKind::Expert => Resource::EgCompute,
            TaskKind::A2E => Resource::A2ELink,
            TaskKind::E2A => Resource::E2ALink,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TaskKind::Attention => "attn",
            TaskKind::SharedExpert => "shared",
            TaskKind::A2E => "a2e",
            TaskKind::Expert => "expert",
            TaskKind::E2A => "e2a",
        }
    }
}

/// One schedulable unit. Dependency edges live in the owning
/// [`Plan`]'s flat pool — see [`Plan::deps`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Task {
    pub kind: TaskKind,
    /// Transformer layer t.
    pub layer: u32,
    /// r1 pipeline chunk i.
    pub chunk: u32,
    /// r2 fine-grained part j (0 for AG-side tasks).
    pub part: u32,
    pub duration: f64,
    /// Offset of this task's dependency slice in `Plan::dep_pool`.
    dep_start: u32,
    /// Length of the dependency slice.
    dep_len: u32,
}

impl Task {
    pub fn resource(&self) -> Resource {
        self.kind.resource()
    }

    pub fn label(&self) -> String {
        match self.kind {
            TaskKind::Attention | TaskKind::SharedExpert => {
                format!("{}[{},{}]", self.kind.name(), self.layer, self.chunk)
            }
            _ => format!("{}[{},{},{}]", self.kind.name(), self.layer, self.chunk, self.part),
        }
    }
}

/// The configuration knobs Algorithm 1 searches over.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanConfig {
    /// Samples per micro-batch per AG GPU (m_a).
    pub m_a: usize,
    /// Pipeline degree of the AG (r1).
    pub r1: usize,
    /// Fine-grained pipeline degree of the EG (r2).
    pub r2: usize,
    /// Tokens per expert per fine-grained part (m_e, derived from token
    /// conservation; fractional values are fine, the models are linear).
    pub m_e: f64,
    pub order: Order,
    /// PPPipe compatibility: treat the shared expert as part of the
    /// attention task (§2.3 "regarding it as a part of attention").
    pub fuse_shared: bool,
}

impl PlanConfig {
    /// A FinDEP configuration (shared expert scheduled separately).
    pub fn findep(m_a: usize, r1: usize, r2: usize, m_e: f64, order: Order) -> Self {
        Self { m_a, r1, r2, m_e, order, fuse_shared: false }
    }

    /// PPPipe (MegaScale-Infer): micro-batch pipelining only — no
    /// fine-grained EG split, shared expert fused into attention.
    pub fn pppipe(m_a: usize, r1: usize, m_e: f64) -> Self {
        Self { m_a, r1, r2: 1, m_e, order: Order::Asas, fuse_shared: true }
    }

    /// Naive DEP: strict sequential handoff (Fig. 3a).
    pub fn naive(m_a: usize, m_e: f64) -> Self {
        Self { m_a, r1: 1, r2: 1, m_e, order: Order::Asas, fuse_shared: true }
    }

    /// Total tokens one forward pass of this configuration processes
    /// across an AG of `ag` GPUs at sequence length `seq_len` — the
    /// numerator of Eq. 6 scaled to tokens. The single source of the
    /// formula: `Plan::build_into` stores it on the plan and the
    /// solver's skip-resimulation path recomputes it from here.
    pub fn total_tokens(&self, ag: usize, seq_len: usize) -> f64 {
        (self.r1 * self.m_a * ag * seq_len) as f64
    }

    pub fn describe(&self) -> String {
        format!(
            "m_a={} r1={} r2={} m_e={:.1} order={}{}",
            self.m_a,
            self.r1,
            self.r2,
            self.m_e,
            self.order.name(),
            if self.fuse_shared { " (shared fused)" } else { "" }
        )
    }
}

/// Identity of a plan's task-DAG *structure*: two canonically-built
/// plans with equal keys have identical tasks (up to duration),
/// identical dependency edges, and identical issue orders — they differ
/// only in task durations (which come from the stage models and
/// `(m_a, m_e)`). This is what lets an outer search (different splits,
/// different micro-batch sizes, same pipeline shape) reuse the
/// simulator's CSR topology and rebuild only durations.
///
/// `shared_tasks` is the collapsed form of `(has_shared, fuse_shared)`:
/// a fused shared expert and an absent shared expert produce the same
/// topology, so they share a key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TopologyKey {
    pub r1: u32,
    pub r2: u32,
    pub order: Order,
    pub shared_tasks: bool,
    pub n_layers: u32,
}

/// A fully-materialized schedule: tasks + precedence + per-resource
/// issue order. Produced by [`Plan::build`] / [`Plan::build_into`],
/// consumed by the simulator and by the real coordinator's pipeline
/// executor.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub config: PlanConfig,
    pub n_layers: usize,
    pub has_shared_tasks: bool,
    pub tasks: Vec<Task>,
    /// Flat dependency pool; `tasks[i]` owns
    /// `dep_pool[dep_start..dep_start+dep_len]`.
    pub(crate) dep_pool: Vec<u32>,
    /// Issue order per resource (indices into `tasks`), FIFO,
    /// non-preemptive.
    pub issue_order: [Vec<u32>; 4],
    /// Total tokens processed per forward pass across the whole AG
    /// (numerator of Eq. 6 scaled to tokens).
    pub total_tokens: f64,
    /// True when this plan was produced by [`Plan::build_into`]'s
    /// canonical layout, which makes [`Plan::topology_key`] a faithful
    /// structural identity. Raw/test-built plans stay `false` so they
    /// can never alias a cached topology.
    canonical: bool,
}

/// Reusable arena for plan construction: Algorithm 1's candidate loop
/// rebuilds the task DAG into the same storage instead of allocating a
/// fresh `Plan` per `(m_a, order, r2)` probe.
#[derive(Debug, Clone)]
pub struct PlanBuffers {
    plan: Plan,
}

impl Default for PlanBuffers {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanBuffers {
    pub fn new() -> Self {
        Self {
            plan: Plan {
                config: PlanConfig::naive(1, 0.0),
                n_layers: 0,
                has_shared_tasks: false,
                tasks: Vec::new(),
                dep_pool: Vec::new(),
                issue_order: [Vec::new(), Vec::new(), Vec::new(), Vec::new()],
                total_tokens: 0.0,
                canonical: false,
            },
        }
    }

    /// The most recently built plan (empty before the first
    /// `build_into`).
    pub fn plan(&self) -> &Plan {
        &self.plan
    }
}

impl Plan {
    /// Build the task DAG for `n_layers` transformer layers with stage
    /// durations from `models` and `ag` AG GPUs contributing
    /// `r1·m_a·S` tokens each. One-shot wrapper over
    /// [`Plan::build_into`].
    pub fn build(
        models: &StageModels,
        cfg: PlanConfig,
        n_layers: usize,
        ag: usize,
        seq_len: usize,
    ) -> Plan {
        let mut buf = PlanBuffers::new();
        Plan::build_into(&mut buf, models, cfg, n_layers, ag, seq_len);
        buf.plan
    }

    /// One-shot wrapper over [`Plan::build_into_loaded`]: a plan whose
    /// expert-part durations carry per-part load factors.
    pub fn build_loaded(
        models: &StageModels,
        cfg: PlanConfig,
        n_layers: usize,
        ag: usize,
        seq_len: usize,
        part_loads: &[f64],
    ) -> Plan {
        let mut buf = PlanBuffers::new();
        Plan::build_into_loaded(&mut buf, models, cfg, n_layers, ag, seq_len, Some(part_loads));
        buf.plan
    }

    /// Rebuild the task DAG in place, reusing `buf`'s task, dependency,
    /// and issue-order storage. Returns a borrow of the built plan.
    /// Output is task-for-task identical to a fresh [`Plan::build`]
    /// (pinned by `rust/tests/plan_properties.rs`).
    pub fn build_into<'a>(
        buf: &'a mut PlanBuffers,
        models: &StageModels,
        cfg: PlanConfig,
        n_layers: usize,
        ag: usize,
        seq_len: usize,
    ) -> &'a Plan {
        Self::build_into_loaded(buf, models, cfg, n_layers, ag, seq_len, None)
    }

    /// [`Plan::build_into`] with optional per-part expert load factors:
    /// the Expert task of fine-grained part `j` runs for
    /// `t_e(m_e · part_loads[j mod len])` instead of the homogeneous
    /// `t_e(m_e)` — how the simulator prices skew-sampled per-part
    /// expert loads (see `config::placement::ExpertLoad::
    /// sample_part_factors`) without re-deriving stage coefficients.
    /// `None` (and equally a slice of exact `1.0`s, since `x·1.0 ≡ x`)
    /// is bit-identical to the legacy builder; the factors apply in
    /// both the full build and the duration-only topology fast path.
    pub fn build_into_loaded<'a>(
        buf: &'a mut PlanBuffers,
        models: &StageModels,
        cfg: PlanConfig,
        n_layers: usize,
        ag: usize,
        seq_len: usize,
        part_loads: Option<&[f64]>,
    ) -> &'a Plan {
        assert!(cfg.r1 >= 1 && cfg.r2 >= 1 && cfg.m_a >= 1);
        if let Some(l) = part_loads {
            assert!(!l.is_empty(), "empty per-part load factors");
        }
        let r1 = cfg.r1;
        let r2 = cfg.r2;
        let shared_tasks = models.has_shared && !cfg.fuse_shared;

        let t_a = models.attn_time(cfg.m_a as f64)
            + if cfg.fuse_shared { models.shared_time(cfg.m_a as f64) } else { 0.0 };
        let t_s = if shared_tasks { models.shared_time(cfg.m_a as f64) } else { 0.0 };
        let t_e = models.expert_time(cfg.m_e);
        let t_c = models.comm_time(cfg.m_e);
        // Per-part expert duration: the homogeneous t_e unless the
        // caller supplied load factors (None reproduces t_e exactly).
        let expert_dur = |j: usize| match part_loads {
            None => t_e,
            Some(l) => models.expert_time(cfg.m_e * l[j % l.len()]),
        };

        // Duration-only fast path: if the arena already holds a plan of
        // this exact topology, only the durations (and the scalar
        // config/token fields) can differ — rewrite those in one pass
        // and skip task/dep/issue-order construction entirely. Output
        // is bit-identical to a full rebuild (pinned by tests).
        let key = TopologyKey {
            r1: r1 as u32,
            r2: r2 as u32,
            order: cfg.order,
            shared_tasks,
            n_layers: n_layers as u32,
        };
        if buf.plan.topology_key() == Some(key) {
            let plan = &mut buf.plan;
            plan.config = cfg;
            plan.total_tokens = cfg.total_tokens(ag, seq_len);
            for t in &mut plan.tasks {
                t.duration = match t.kind {
                    TaskKind::Attention => t_a,
                    TaskKind::SharedExpert => t_s,
                    TaskKind::Expert => expert_dur(t.part as usize),
                    TaskKind::A2E | TaskKind::E2A => t_c,
                };
            }
            return &buf.plan;
        }

        let n_sh = if shared_tasks { r1 } else { 0 };
        let per_layer = r1 + n_sh + 3 * r1 * r2;

        let plan = &mut buf.plan;
        plan.config = cfg;
        plan.n_layers = n_layers;
        plan.has_shared_tasks = shared_tasks;
        plan.canonical = true;
        plan.total_tokens = cfg.total_tokens(ag, seq_len);
        let tasks = &mut plan.tasks;
        let pool = &mut plan.dep_pool;
        tasks.clear();
        pool.clear();
        tasks.reserve(per_layer * n_layers);

        // Arithmetic index helpers (layout per layer: attn | shared |
        // a2e | expert | e2a).
        let base = |t: usize| t * per_layer;
        let idx_attn = |t: usize, i: usize| (base(t) + i) as u32;
        let idx_shared = |t: usize, i: usize| (base(t) + r1 + i) as u32;
        let idx_a2e = |t: usize, i: usize, j: usize| (base(t) + r1 + n_sh + i * r2 + j) as u32;
        let idx_expert =
            |t: usize, i: usize, j: usize| (base(t) + r1 + n_sh + r1 * r2 + i * r2 + j) as u32;
        let idx_e2a =
            |t: usize, i: usize, j: usize| (base(t) + r1 + n_sh + 2 * r1 * r2 + i * r2 + j) as u32;

        // Push a task whose deps were just appended to the pool.
        let push = |tasks: &mut Vec<Task>,
                        pool: &mut Vec<u32>,
                        dep_start: usize,
                        kind: TaskKind,
                        layer: usize,
                        chunk: usize,
                        part: usize,
                        duration: f64| {
            tasks.push(Task {
                kind,
                layer: layer as u32,
                chunk: chunk as u32,
                part: part as u32,
                duration,
                dep_start: dep_start as u32,
                dep_len: (pool.len() - dep_start) as u32,
            });
        };

        for t in 0..n_layers {
            // Attention chunks.
            for i in 0..r1 {
                let dep_start = pool.len();
                if t > 0 {
                    // Rule 9: next-layer attention needs all E2A parts of
                    // its chunk and (if present) its shared segment.
                    for j in 0..r2 {
                        pool.push(idx_e2a(t - 1, i, j));
                    }
                    if shared_tasks {
                        pool.push(idx_shared(t - 1, i));
                    }
                }
                push(tasks, pool, dep_start, TaskKind::Attention, t, i, 0, t_a);
            }
            // Shared-expert chunks (rule 6: after own attention).
            if shared_tasks {
                for i in 0..r1 {
                    let dep_start = pool.len();
                    pool.push(idx_attn(t, i));
                    push(tasks, pool, dep_start, TaskKind::SharedExpert, t, i, 0, t_s);
                }
            }
            // A2E parts (rule 6: after own attention chunk).
            for i in 0..r1 {
                for j in 0..r2 {
                    let dep_start = pool.len();
                    pool.push(idx_attn(t, i));
                    push(tasks, pool, dep_start, TaskKind::A2E, t, i, j, t_c);
                }
            }
            // Expert parts (rule 7).
            for i in 0..r1 {
                for j in 0..r2 {
                    let dep_start = pool.len();
                    pool.push(idx_a2e(t, i, j));
                    push(tasks, pool, dep_start, TaskKind::Expert, t, i, j, expert_dur(j));
                }
            }
            // E2A parts (rule 8).
            for i in 0..r1 {
                for j in 0..r2 {
                    let dep_start = pool.len();
                    pool.push(idx_expert(t, i, j));
                    push(tasks, pool, dep_start, TaskKind::E2A, t, i, j, t_c);
                }
            }
        }

        // Issue orders.
        let [ag_order, eg_order, a2e_order, e2a_order] = &mut plan.issue_order;
        ag_order.clear();
        eg_order.clear();
        a2e_order.clear();
        e2a_order.clear();
        for t in 0..n_layers {
            match cfg.order {
                Order::Asas => {
                    for i in 0..r1 {
                        ag_order.push(idx_attn(t, i));
                        if shared_tasks {
                            ag_order.push(idx_shared(t, i));
                        }
                    }
                }
                Order::Aass => {
                    for i in 0..r1 {
                        ag_order.push(idx_attn(t, i));
                    }
                    if shared_tasks {
                        for i in 0..r1 {
                            ag_order.push(idx_shared(t, i));
                        }
                    }
                }
            }
        }
        for t in 0..n_layers {
            for i in 0..r1 {
                for j in 0..r2 {
                    a2e_order.push(idx_a2e(t, i, j));
                    eg_order.push(idx_expert(t, i, j));
                    e2a_order.push(idx_e2a(t, i, j));
                }
            }
        }

        &buf.plan
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Structural identity of this plan's task DAG (see
    /// [`TopologyKey`]), or `None` for plans not produced by the
    /// canonical builder. Consumers (the simulator's topology cache,
    /// the duration-only rebuild path) treat equal keys as a guarantee
    /// of identical dependency edges and issue orders; mutating a built
    /// plan's structure by hand voids that guarantee.
    pub fn topology_key(&self) -> Option<TopologyKey> {
        if !self.canonical {
            return None;
        }
        Some(TopologyKey {
            r1: self.config.r1 as u32,
            r2: self.config.r2 as u32,
            order: self.config.order,
            shared_tasks: self.has_shared_tasks,
            n_layers: self.n_layers as u32,
        })
    }

    /// Dependency edges of task `i` (indices of tasks that must finish
    /// before it may start).
    pub fn deps(&self, i: usize) -> &[u32] {
        let t = &self.tasks[i];
        &self.dep_pool[t.dep_start as usize..(t.dep_start + t.dep_len) as usize]
    }

    /// Total number of dependency edges.
    pub fn n_dep_edges(&self) -> usize {
        self.dep_pool.len()
    }

    /// Index lookup by identity (test/diagnostic path; O(n)).
    pub fn find(&self, kind: TaskKind, layer: u32, chunk: u32, part: u32) -> Option<usize> {
        self.tasks.iter().position(|t| {
            t.kind == kind && t.layer == layer && t.chunk == chunk && t.part == part
        })
    }

    /// Construct a plan from raw parts (crate-internal: lets simulator
    /// tests exercise malformed/cyclic inputs that `build` can never
    /// produce).
    #[cfg(test)]
    pub(crate) fn from_raw_parts(
        tasks: Vec<(TaskKind, f64, Vec<u32>)>,
        issue_order: [Vec<u32>; 4],
    ) -> Plan {
        let mut out_tasks = Vec::with_capacity(tasks.len());
        let mut pool = Vec::new();
        for (kind, duration, deps) in tasks {
            let dep_start = pool.len() as u32;
            pool.extend_from_slice(&deps);
            out_tasks.push(Task {
                kind,
                layer: 0,
                chunk: out_tasks.len() as u32,
                part: 0,
                duration,
                dep_start,
                dep_len: deps.len() as u32,
            });
        }
        Plan {
            config: PlanConfig::naive(1, 0.0),
            n_layers: 1,
            has_shared_tasks: false,
            tasks: out_tasks,
            dep_pool: pool,
            issue_order,
            total_tokens: 1.0,
            canonical: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GroupSplit, ModelConfig, Testbed};

    fn models(shared: bool) -> StageModels {
        let m = if shared { ModelConfig::deepseek_v2(4) } else { ModelConfig::qwen3_moe(4) };
        let split = if shared { GroupSplit::new(3, 5) } else { GroupSplit::new(4, 4) };
        StageModels::new(&m, &Testbed::a(), split, 2048)
    }

    fn cfg(r1: usize, r2: usize, order: Order) -> PlanConfig {
        PlanConfig::findep(2, r1, r2, 64.0, order)
    }

    #[test]
    fn task_counts() {
        let sm = models(true);
        let p = Plan::build(&sm, cfg(2, 3, Order::Asas), 4, 3, 2048);
        // per layer: 2 attn + 2 shared + 3*2*3 = 22; 4 layers = 88
        assert_eq!(p.n_tasks(), 88);
        let q = Plan::build(&models(false), cfg(2, 3, Order::Asas), 4, 4, 2048);
        // no shared tasks: per layer 2 + 18 = 20; 4 layers = 80
        assert_eq!(q.n_tasks(), 80);
        assert!(!q.has_shared_tasks);
    }

    #[test]
    fn pppipe_fuses_shared() {
        let sm = models(true);
        let p = Plan::build(&sm, PlanConfig::pppipe(2, 2, 128.0), 2, 3, 2048);
        assert!(!p.has_shared_tasks);
        // Fused attention task must absorb the shared time.
        let attn = &p.tasks[p.find(TaskKind::Attention, 0, 0, 0).unwrap()];
        assert!((attn.duration - (sm.attn_time(2.0) + sm.shared_time(2.0))).abs() < 1e-12);
    }

    #[test]
    fn dependency_rules_hold() {
        let sm = models(true);
        let p = Plan::build(&sm, cfg(2, 2, Order::Asas), 3, 3, 2048);
        // Rule 6: shared after its attention.
        let sh = p.find(TaskKind::SharedExpert, 1, 1, 0).unwrap();
        let at = p.find(TaskKind::Attention, 1, 1, 0).unwrap() as u32;
        assert!(p.deps(sh).contains(&at));
        // Rule 6/7/8 chain for a fine-grained part.
        let a2e = p.find(TaskKind::A2E, 1, 0, 1).unwrap();
        let at10 = p.find(TaskKind::Attention, 1, 0, 0).unwrap() as u32;
        assert!(p.deps(a2e).contains(&at10));
        let ex = p.find(TaskKind::Expert, 1, 0, 1).unwrap();
        assert!(p.deps(ex).contains(&(a2e as u32)));
        let e2a = p.find(TaskKind::E2A, 1, 0, 1).unwrap();
        assert!(p.deps(e2a).contains(&(ex as u32)));
        // Rule 9: layer-2 attention of chunk 0 depends on both layer-1
        // E2A parts of chunk 0 and layer-1 shared of chunk 0.
        let at2 = p.find(TaskKind::Attention, 2, 0, 0).unwrap();
        let e2a0 = p.find(TaskKind::E2A, 1, 0, 0).unwrap() as u32;
        let e2a1 = p.find(TaskKind::E2A, 1, 0, 1).unwrap() as u32;
        let sh0 = p.find(TaskKind::SharedExpert, 1, 0, 0).unwrap() as u32;
        for d in [e2a0, e2a1, sh0] {
            assert!(p.deps(at2).contains(&d), "missing dep {d}");
        }
    }

    #[test]
    fn issue_orders_cover_all_tasks_once() {
        let sm = models(true);
        let p = Plan::build(&sm, cfg(3, 2, Order::Aass), 2, 3, 2048);
        let total: usize = p.issue_order.iter().map(|v| v.len()).sum();
        assert_eq!(total, p.n_tasks());
        let mut seen = vec![false; p.n_tasks()];
        for q in &p.issue_order {
            for &t in q {
                assert!(!seen[t as usize], "task issued twice");
                seen[t as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        // Each queue only contains its own resource's tasks.
        for (ri, q) in p.issue_order.iter().enumerate() {
            for &t in q {
                assert_eq!(p.tasks[t as usize].resource().index(), ri);
            }
        }
    }

    #[test]
    fn asas_vs_aass_orders_differ() {
        let sm = models(true);
        let asas = Plan::build(&sm, cfg(2, 1, Order::Asas), 1, 3, 2048);
        let aass = Plan::build(&sm, cfg(2, 1, Order::Aass), 1, 3, 2048);
        assert_ne!(asas.issue_order[0], aass.issue_order[0]);
        // ASAS: A0 S0 A1 S1; AASS: A0 A1 S0 S1.
        let kinds = |p: &Plan| -> Vec<TaskKind> {
            p.issue_order[0].iter().map(|&t| p.tasks[t as usize].kind).collect()
        };
        use TaskKind::*;
        assert_eq!(kinds(&asas), vec![Attention, SharedExpert, Attention, SharedExpert]);
        assert_eq!(kinds(&aass), vec![Attention, Attention, SharedExpert, SharedExpert]);
    }

    #[test]
    fn deps_point_backwards_in_issue_order() {
        // Guarantees deadlock-freedom of FIFO in-order execution: the
        // union of dependency and resource-order edges is acyclic
        // (Kahn's algorithm consumes every task).
        let sm = models(true);
        for order in Order::both() {
            let p = Plan::build(&sm, cfg(3, 3, order), 3, 3, 2048);
            let mut indeg = vec![0usize; p.n_tasks()];
            let mut dependents: Vec<Vec<u32>> = vec![Vec::new(); p.n_tasks()];
            for i in 0..p.n_tasks() {
                indeg[i] = p.deps(i).len();
                for &d in p.deps(i) {
                    dependents[d as usize].push(i as u32);
                }
            }
            for q in &p.issue_order {
                for w in q.windows(2) {
                    dependents[w[0] as usize].push(w[1]);
                    indeg[w[1] as usize] += 1;
                }
            }
            let mut ready: Vec<usize> =
                indeg.iter().enumerate().filter(|(_, &d)| d == 0).map(|(i, _)| i).collect();
            let mut done = 0usize;
            while let Some(i) = ready.pop() {
                done += 1;
                for &n in &dependents[i] {
                    indeg[n as usize] -= 1;
                    if indeg[n as usize] == 0 {
                        ready.push(n as usize);
                    }
                }
            }
            assert_eq!(done, p.n_tasks(), "cycle in plan ({})", order.name());
        }
    }

    #[test]
    fn total_tokens_counts_whole_ag() {
        let sm = models(true);
        let p = Plan::build(&sm, cfg(2, 1, Order::Asas), 2, 3, 2048);
        // r1=2, m_a=2, ag=3, S=2048
        assert_eq!(p.total_tokens, (2 * 2 * 3 * 2048) as f64);
    }

    #[test]
    fn build_into_reuses_storage_and_matches_build() {
        let sm = models(true);
        let mut buf = PlanBuffers::new();
        // First build sizes the arena.
        Plan::build_into(&mut buf, &sm, cfg(3, 4, Order::Asas), 4, 3, 2048);
        let cap_tasks = buf.plan.tasks.capacity();
        let cap_pool = buf.plan.dep_pool.capacity();
        // A smaller rebuild must not reallocate and must equal a fresh
        // build exactly.
        for c in [cfg(2, 2, Order::Aass), cfg(3, 4, Order::Asas), cfg(1, 1, Order::Asas)] {
            let reused = Plan::build_into(&mut buf, &sm, c, 4, 3, 2048).clone();
            let fresh = Plan::build(&sm, c, 4, 3, 2048);
            assert_eq!(reused, fresh, "build_into drifted from build for {}", c.describe());
        }
        assert_eq!(buf.plan.tasks.capacity(), cap_tasks, "task arena reallocated");
        assert_eq!(buf.plan.dep_pool.capacity(), cap_pool, "dep arena reallocated");
    }

    #[test]
    fn duration_only_rebuild_matches_full_build() {
        // Same (r1, r2, order, shared, layers) with a different m_a /
        // m_e (and even different stage models) takes the duration-only
        // fast path — the result must be bit-identical to a fresh
        // build, and the topology key must be stable.
        let sm_a = models(true);
        let sm_b = StageModels::new(
            &ModelConfig::deepseek_v2(4),
            &Testbed::b(),
            GroupSplit::new(3, 5),
            2048,
        );
        let mut buf = PlanBuffers::new();
        Plan::build_into(&mut buf, &sm_a, cfg(2, 3, Order::Asas), 4, 3, 2048);
        let key = buf.plan().topology_key().expect("built plans are canonical");
        for (sm, m_a, m_e, seq) in
            [(&sm_a, 4usize, 96.0f64, 2048usize), (&sm_b, 1, 12.5, 4096), (&sm_a, 2, 64.0, 2048)]
        {
            let c = PlanConfig::findep(m_a, 2, 3, m_e, Order::Asas);
            let reused = Plan::build_into(&mut buf, sm, c, 4, 3, seq).clone();
            let fresh = Plan::build(sm, c, 4, 3, seq);
            assert_eq!(reused, fresh, "duration-only rebuild drifted for {}", c.describe());
            assert_eq!(reused.topology_key(), Some(key));
        }
        // A topology change (different r2) must fall back to a full
        // rebuild and still match.
        let c = PlanConfig::findep(2, 2, 4, 48.0, Order::Aass);
        let reused = Plan::build_into(&mut buf, &sm_a, c, 4, 3, 2048).clone();
        assert_eq!(reused, Plan::build(&sm_a, c, 4, 3, 2048));
        assert_ne!(reused.topology_key(), Some(key));
    }

    #[test]
    fn loaded_build_prices_skewed_parts_and_unit_factors_are_identity() {
        let sm = models(true);
        let c = cfg(2, 3, Order::Asas);
        let base = Plan::build(&sm, c, 4, 3, 2048);
        // None (via build) and exact-1.0 factors are bit-identical.
        let ones = Plan::build_loaded(&sm, c, 4, 3, 2048, &[1.0, 1.0, 1.0]);
        assert_eq!(base, ones);
        // Skewed factors: Expert durations move per part, everything
        // else stays bit-identical, and the duration-only fast path
        // agrees with a fresh loaded build.
        let loads = [1.75, 0.5, 0.75];
        let fresh = Plan::build_loaded(&sm, c, 4, 3, 2048, &loads);
        let mut buf = PlanBuffers::new();
        Plan::build_into(&mut buf, &sm, c, 4, 3, 2048);
        let reused =
            Plan::build_into_loaded(&mut buf, &sm, c, 4, 3, 2048, Some(&loads)).clone();
        assert_eq!(reused, fresh, "loaded fast path drifted from full build");
        for (a, b) in base.tasks.iter().zip(fresh.tasks.iter()) {
            if a.kind == TaskKind::Expert {
                let want = sm.expert_time(c.m_e * loads[a.part as usize]);
                assert_eq!(b.duration.to_bits(), want.to_bits());
            } else {
                assert_eq!(a.duration.to_bits(), b.duration.to_bits());
            }
        }
    }

    #[test]
    fn topology_key_collapses_fused_and_absent_shared() {
        // Fused-shared (DeepSeek, fuse_shared) and no-shared (Qwen)
        // plans have no shared tasks — identical topologies, one key.
        let with = models(true);
        let without = models(false);
        let mut fused = cfg(2, 2, Order::Asas);
        fused.fuse_shared = true;
        let a = Plan::build(&with, fused, 3, 3, 2048);
        let b = Plan::build(&without, cfg(2, 2, Order::Asas), 3, 4, 2048);
        assert_eq!(a.topology_key(), b.topology_key());
        // Separately-scheduled shared tasks change the topology.
        let c = Plan::build(&with, cfg(2, 2, Order::Asas), 3, 3, 2048);
        assert_ne!(a.topology_key(), c.topology_key());
        // Raw plans carry no key.
        let raw = Plan::from_raw_parts(
            vec![(TaskKind::Expert, 1.0, vec![])],
            [Vec::new(), vec![0], Vec::new(), Vec::new()],
        );
        assert_eq!(raw.topology_key(), None);
    }

    #[test]
    fn dep_slices_are_consistent() {
        let sm = models(true);
        let p = Plan::build(&sm, cfg(2, 3, Order::Asas), 3, 3, 2048);
        let total: usize = (0..p.n_tasks()).map(|i| p.deps(i).len()).sum();
        assert_eq!(total, p.n_dep_edges());
        for i in 0..p.n_tasks() {
            for &d in p.deps(i) {
                assert!((d as usize) < p.n_tasks(), "dangling dep {d}");
            }
        }
    }
}
