//! Golden numerics: the Rust DEP pipeline (AOT artifacts on PJRT-CPU,
//! fine-grained scheduling, real routing) must reproduce the Python
//! kernel-path forward bit-for-bit within tolerance — for both the
//! shared-expert (DeepSeek-style) and no-shared (Qwen-style) variants,
//! under several schedules.

use findep::coordinator::moe::ModelHandle;
use findep::coordinator::pipeline::{ExecConfig, Pipeline};
use findep::runtime::artifact::{Golden, Manifest};
use findep::runtime::artifacts_dir;
use findep::sched::Order;

fn skip() -> bool {
    let missing = !artifacts_dir().join("manifest.json").exists();
    if missing {
        eprintln!("skipping: run `make artifacts` first");
    }
    missing
}

fn check_variant(shared: bool, cfgs: &[ExecConfig]) {
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir).unwrap();
    let golden_path = if shared { &manifest.golden } else { &manifest.golden_noshared };
    let golden = Golden::load(golden_path).unwrap();
    let model = ModelHandle::load(&dir, shared).unwrap();
    let pipeline = Pipeline::new(model, 2, None).unwrap();
    for &cfg in cfgs {
        let (out, _) = pipeline.forward(&golden.input, cfg).unwrap();
        let diff = out.max_abs_diff(&golden.output);
        assert!(
            diff <= golden.atol,
            "golden mismatch (shared={shared}, cfg={cfg:?}): maxdiff {diff} > atol {}",
            golden.atol
        );
    }
}

#[test]
fn golden_shared_model_all_schedules() {
    if skip() {
        return;
    }
    check_variant(
        true,
        &[
            ExecConfig::naive(),
            ExecConfig::pppipe(2),
            ExecConfig::findep(1, 1, Order::Asas),
            ExecConfig::findep(2, 2, Order::Asas),
            ExecConfig::findep(2, 4, Order::Aass),
        ],
    );
}

#[test]
fn golden_noshared_model() {
    if skip() {
        return;
    }
    check_variant(
        false,
        &[ExecConfig::naive(), ExecConfig::findep(2, 2, Order::Asas)],
    );
}

#[test]
fn golden_robust_to_eg_worker_count() {
    if skip() {
        return;
    }
    let dir = artifacts_dir();
    let manifest = Manifest::load(&dir).unwrap();
    let golden = Golden::load(&manifest.golden).unwrap();
    for eg in [1usize, 3, 4, 8] {
        let model = ModelHandle::load(&dir, true).unwrap();
        let pipeline = Pipeline::new(model, eg, None).unwrap();
        let (out, _) =
            pipeline.forward(&golden.input, ExecConfig::findep(2, 2, Order::Asas)).unwrap();
        let diff = out.max_abs_diff(&golden.output);
        assert!(diff <= golden.atol, "eg={eg}: maxdiff {diff}");
    }
}
