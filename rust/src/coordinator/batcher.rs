//! Continuous batching: a bounded request queue in front of a pool of
//! pipelined serving workers (the EPS-MoE / MegaScale-Infer serving
//! shape — many in-flight micro-batches keep the disaggregated
//! attention/expert groups busy).
//!
//! ```text
//!   submit() ──▶ bounded queue ──▶ assembler (FIFO, linger window,
//!        │                    ▲    mixed prefill/decode batches)
//!        │       decode steps │         │ bounded work channel
//!        │       (KV-growing  │         ▼
//!        │        re-entry)   │  worker 0 .. W-1  (one Server +
//!        │                    └── pipeline replica each; shared
//!        │                        Registry + PlanCache)
//!        │                              │
//!        ◀──── final responses ─────────┘
//! ```
//!
//! Invariants:
//!
//! * **FIFO draining** — the assembler forms batches strictly in
//!   arrival order; with one worker and no decode traffic, responses
//!   come back in submission order regardless of how the stream was cut
//!   into batches. Decode re-entries take priority over fresh
//!   submissions (finish what is in flight), so equal-output requests
//!   still complete in submission order.
//! * **Continuous decode batching** — a request submitted with
//!   `output_len > 0` re-enters the queue after its prefill as one
//!   decode step per output token, KV growing each step; each window
//!   may therefore mix phases, and the server schedules its prefill and
//!   decode chunks under separate phase-keyed cached plans. The client
//!   receives exactly one response, after the last step.
//! * **Backpressure** — the submit queue is a bounded `sync_channel`:
//!   `submit` blocks when the queue is full, `try_submit` rejects (and
//!   counts `queue_rejected`). The decode re-entry lane is unbounded so
//!   workers can never deadlock against a full queue; its depth is
//!   bounded by the requests already admitted.
//! * **Per-request latency** — each final response's `latency_s` is
//!   rewritten to the true submit→response time (prefill plus every
//!   decode step), and each queue pass's wait lands in the shared
//!   registry's `queue_wait` histogram.
//! * **Shared planning** — workers share one [`PlanCache`], so an
//!   Adaptive shape solved on any worker is a hit on all of them —
//!   prefill and decode shapes memoized separately.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{
    channel, sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TryRecvError,
    TrySendError,
};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::Phase;
use crate::coordinator::links::LinkDelay;
use crate::coordinator::moe::ModelHandle;
use crate::coordinator::server::{EmbeddedRequest, Policy, Response, Server};
use crate::metrics::Registry;
use crate::solver::PlanCache;

/// How often the assembler re-polls the decode re-entry lane while
/// blocked waiting for fresh submissions.
const DECODE_POLL: Duration = Duration::from_micros(200);

/// A request plus its timestamps: `enqueued` is when *this entry*
/// joined the stream (the queue-wait reference — a decode step's wait
/// counts from its re-entry), `submitted` is the original client
/// submission (the end-to-end latency reference for the final
/// response).
struct QueuedRequest {
    req: EmbeddedRequest,
    enqueued: Instant,
    submitted: Instant,
}

/// Continuous-batcher knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// EG workers per pipeline replica.
    pub eg: usize,
    /// Optional α-β link delay per replica.
    pub link_delay: Option<LinkDelay>,
    /// Scheduling policy applied to every assembled batch.
    pub policy: Policy,
    /// Most requests per assembled batch (the size bucket cap).
    pub max_batch: usize,
    /// Bounded submit-queue depth (`submit` blocks beyond it).
    pub queue_depth: usize,
    /// Serving workers = pipeline replicas = in-flight batches.
    pub workers: usize,
    /// How long the assembler waits to fill a batch after the first
    /// request arrives.
    pub linger: Duration,
    /// Memoize Adaptive plans per shape (shared across workers).
    pub cache_plans: bool,
    /// Pick each replica's Adaptive planning split with the split-search
    /// solver layer at startup instead of the fixed `(1, eg)` view.
    pub auto_split: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            eg: 2,
            link_delay: None,
            policy: Policy::Adaptive,
            max_batch: 8,
            queue_depth: 64,
            workers: 2,
            linger: Duration::from_millis(1),
            cache_plans: true,
            auto_split: false,
        }
    }
}

/// The continuous batcher: owns the queue, the assembler, and the
/// worker pool. Dropping it drains in-flight work and joins every
/// thread.
pub struct Batcher {
    submit_tx: Option<SyncSender<QueuedRequest>>,
    resp_rx: Receiver<Response>,
    metrics: Arc<Registry>,
    plan_cache: Arc<PlanCache>,
    /// Expected `S·M` element count per request — malformed requests
    /// are rejected at submit time so they can never sink a whole
    /// assembled batch inside a worker.
    req_elems: usize,
    /// Requests still owed a final response (in the queue, in flight,
    /// or looping through decode re-entry).
    open: Arc<AtomicUsize>,
    threads: Vec<JoinHandle<()>>,
}

impl Batcher {
    /// Spin up the assembler and `cfg.workers` serving replicas over
    /// one loaded model, planning against the hand-written testbed
    /// constants.
    pub fn new(model: ModelHandle, cfg: BatcherConfig) -> Result<Batcher> {
        Self::with_profile(model, cfg, None)
    }

    /// [`Batcher::new`] with every replica's Adaptive planner driven by
    /// a calibration profile's measured constants. The profile is
    /// applied before the optional auto-split selection, so the split
    /// itself is chosen under the calibrated view; its fingerprint
    /// rides every plan-cache key, keeping calibrated and
    /// hand-constant plans in disjoint keyspaces of the shared cache.
    pub fn with_profile(
        model: ModelHandle,
        cfg: BatcherConfig,
        profile: Option<&crate::perfmodel::profile::CalibrationProfile>,
    ) -> Result<Batcher> {
        let metrics = Arc::new(Registry::new());
        let plan_cache = Arc::new(PlanCache::new());
        let workers = cfg.workers.max(1);
        let max_batch = cfg.max_batch.max(1);
        let req_elems = model.seq_len * model.model.embed;

        let (submit_tx, submit_rx) = sync_channel::<QueuedRequest>(cfg.queue_depth.max(1));
        // Decode re-entry lane: workers push finished-prefill requests
        // back as KV-grown decode steps. Unbounded on purpose — a
        // worker must never block re-entering its own output while the
        // assembler blocks handing it the next batch (that cycle would
        // deadlock the pool); depth is bounded anyway by
        // `open` ≤ queue_depth + workers·max_batch in-flight requests.
        let (decode_tx, decode_rx) = channel::<QueuedRequest>();
        // Requests inside the system that still owe the client a final
        // response; shutdown drains until this reaches zero so pending
        // decode steps are never dropped.
        let open = Arc::new(AtomicUsize::new(0));
        // Bounded work channel: the assembler runs at most `workers`
        // batches ahead of the slowest replica.
        let (work_tx, work_rx) = sync_channel::<Vec<QueuedRequest>>(workers);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (resp_tx, resp_rx) = channel::<Response>();

        let mut threads = Vec::with_capacity(workers + 1);
        // The split search is deterministic in (model, plan testbed,
        // seq), so run it on the first replica only and hand the chosen
        // split to the rest — re-running it per worker would also
        // re-clear the shared plan cache under the earlier workers.
        let mut chosen_split = None;
        {
            let metrics = metrics.clone();
            let linger = cfg.linger;
            let open = open.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("findep-batcher".into())
                    .spawn(move || {
                        assembler_loop(
                            submit_rx, decode_rx, work_tx, max_batch, linger, open, metrics,
                        )
                    })
                    .context("spawn batch assembler")?,
            );
        }
        for w in 0..workers {
            let mut server = Server::with_shared(
                model.clone(),
                cfg.eg,
                cfg.link_delay,
                metrics.clone(),
                plan_cache.clone(),
            )?;
            server.cache_plans = cfg.cache_plans;
            if let Some(p) = profile {
                server.set_calibration_profile(p);
            }
            if cfg.auto_split {
                match chosen_split {
                    None => chosen_split = Some(server.select_plan_split()),
                    Some(split) => server.plan_split = split,
                }
            }
            let work_rx = work_rx.clone();
            let resp_tx = resp_tx.clone();
            let decode_tx = decode_tx.clone();
            let open = open.clone();
            let policy = cfg.policy;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("findep-serve{w}"))
                    .spawn(move || worker_loop(server, policy, work_rx, resp_tx, decode_tx, open))
                    .context("spawn serving worker")?,
            );
        }

        Ok(Batcher {
            submit_tx: Some(submit_tx),
            resp_rx,
            metrics,
            plan_cache,
            req_elems,
            open,
            threads,
        })
    }

    /// A malformed request must fail at the submission boundary — once
    /// assembled, `serve_batch` would reject the whole batch and every
    /// co-batched request would silently lose its response.
    fn validate(&self, req: &EmbeddedRequest) -> Result<()> {
        anyhow::ensure!(
            req.hidden.data.len() == self.req_elems,
            "request {} has {} elements, expected {} (S·M)",
            req.id,
            req.hidden.data.len(),
            self.req_elems
        );
        Ok(())
    }

    /// Enqueue a request, blocking while the queue is full
    /// (backpressure). Errors on malformed requests or after shutdown.
    /// A request with `output_len > 0` re-enters the stream as that
    /// many KV-growing decode steps after its prefill completes; the
    /// single response arrives once the last step finishes.
    pub fn submit(&self, req: EmbeddedRequest) -> Result<()> {
        self.validate(&req)?;
        let tx = self.submit_tx.as_ref().context("batcher closed")?;
        self.open.fetch_add(1, Ordering::SeqCst);
        let now = Instant::now();
        if tx.send(QueuedRequest { req, enqueued: now, submitted: now }).is_err() {
            self.open.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("batcher workers gone");
        }
        self.metrics.inc("queued", 1);
        Ok(())
    }

    /// Non-blocking enqueue: `Ok(false)` when the queue is full (the
    /// request is rejected and counted).
    pub fn try_submit(&self, req: EmbeddedRequest) -> Result<bool> {
        self.validate(&req)?;
        let tx = self.submit_tx.as_ref().context("batcher closed")?;
        self.open.fetch_add(1, Ordering::SeqCst);
        let now = Instant::now();
        match tx.try_send(QueuedRequest { req, enqueued: now, submitted: now }) {
            Ok(()) => {
                self.metrics.inc("queued", 1);
                Ok(true)
            }
            Err(TrySendError::Full(_)) => {
                self.open.fetch_sub(1, Ordering::SeqCst);
                self.metrics.inc("queue_rejected", 1);
                Ok(false)
            }
            Err(TrySendError::Disconnected(_)) => {
                self.open.fetch_sub(1, Ordering::SeqCst);
                anyhow::bail!("batcher workers gone")
            }
        }
    }

    /// Next completed response, or `None` on timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        self.resp_rx.recv_timeout(timeout).ok()
    }

    /// Collect up to `n` responses, waiting at most `timeout` for each.
    pub fn drain(&self, n: usize, timeout: Duration) -> Vec<Response> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            match self.recv_timeout(timeout) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    pub fn metrics(&self) -> &Arc<Registry> {
        &self.metrics
    }

    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plan_cache
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // Close the queue: the assembler drains what's pending, then
        // the work channel closes and every worker exits.
        self.submit_tx.take();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Pop the next request for assembly. Decode re-entries take priority
/// over fresh submissions (finish what is in flight — the standard
/// continuous-batching discipline, and the one that bounds per-request
/// completion time). Blocks until something arrives; returns `None`
/// only when the submit side has closed *and* no request still owes a
/// response (`open == 0`), so pending decode loops always drain.
fn next_request(
    submit_rx: &Receiver<QueuedRequest>,
    decode_rx: &Receiver<QueuedRequest>,
    open: &AtomicUsize,
) -> Option<QueuedRequest> {
    loop {
        if let Ok(q) = decode_rx.try_recv() {
            return Some(q);
        }
        match submit_rx.recv_timeout(DECODE_POLL) {
            Ok(q) => return Some(q),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Submissions closed: drain the in-flight decode work. A
    // disconnected decode lane means every worker has exited — no step
    // can ever arrive again, so stop even if `open` never reached zero
    // (a crashed worker's requests are lost either way; spinning here
    // would hang shutdown).
    loop {
        match decode_rx.recv_timeout(DECODE_POLL) {
            Ok(q) => return Some(q),
            Err(RecvTimeoutError::Disconnected) => return None,
            Err(RecvTimeoutError::Timeout) => {}
        }
        if open.load(Ordering::SeqCst) == 0 {
            return None;
        }
    }
}

/// FIFO batch assembly with a linger window: take the first request
/// (blocking), then fill up to `max_batch` from whatever arrives within
/// `linger` — decode re-entries first, then fresh submissions — so a
/// window naturally forms a *mixed* batch that the server splits into
/// its prefill and decode chunks.
fn assembler_loop(
    submit_rx: Receiver<QueuedRequest>,
    decode_rx: Receiver<QueuedRequest>,
    work_tx: SyncSender<Vec<QueuedRequest>>,
    max_batch: usize,
    linger: Duration,
    open: Arc<AtomicUsize>,
    metrics: Arc<Registry>,
) {
    let mut submit_open = true;
    loop {
        let Some(first) = next_request(&submit_rx, &decode_rx, &open) else {
            return; // closed and fully drained
        };
        let mut batch = Vec::with_capacity(max_batch);
        batch.push(first);
        let deadline = Instant::now() + linger;
        while batch.len() < max_batch {
            if let Ok(q) = decode_rx.try_recv() {
                batch.push(q);
                continue;
            }
            if submit_open {
                match submit_rx.try_recv() {
                    Ok(q) => {
                        batch.push(q);
                        continue;
                    }
                    Err(TryRecvError::Disconnected) => submit_open = false,
                    Err(TryRecvError::Empty) => {}
                }
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                break;
            }
            if submit_open {
                match submit_rx.recv_timeout(remaining.min(DECODE_POLL)) {
                    Ok(q) => batch.push(q),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => submit_open = false,
                }
            } else {
                // Only decode re-entries can still arrive; poll them at
                // the same cadence for the rest of the window.
                std::thread::sleep(remaining.min(DECODE_POLL));
            }
        }
        for q in &batch {
            metrics.observe("queue_wait", q.enqueued.elapsed().as_secs_f64());
        }
        metrics.inc("batches_assembled", 1);
        metrics.observe("batch_fill", batch.len() as f64);
        if work_tx.send(batch).is_err() {
            return; // all workers gone
        }
    }
}

/// Releases a batch's `open` slots when dropped — including during a
/// panic unwind, so a worker dying mid-batch can never strand the
/// assembler's shutdown drain waiting on slots nobody will release.
/// Requests that re-enter as decode steps re-add their slot explicitly
/// before this guard drops (transient over-count, never under-count —
/// the drain must not observe a spurious zero).
struct OpenSlots<'a> {
    open: &'a AtomicUsize,
    n: usize,
}

impl Drop for OpenSlots<'_> {
    fn drop(&mut self) {
        self.open.fetch_sub(self.n, Ordering::SeqCst);
    }
}

/// One serving replica: pop the next assembled batch, serve it, then
/// per request either re-enqueue the next KV-grown decode step (output
/// remaining) or emit the final response with its true
/// submit→response latency.
fn worker_loop(
    server: Server,
    policy: Policy,
    work_rx: Arc<Mutex<Receiver<Vec<QueuedRequest>>>>,
    resp_tx: Sender<Response>,
    decode_tx: Sender<QueuedRequest>,
    open: Arc<AtomicUsize>,
) {
    let prompt_len = server.pipeline.model().seq_len;
    loop {
        // Hold the lock only for the pop; serving runs unlocked so the
        // other replicas pipeline their own batches meanwhile.
        let batch = {
            let rx = work_rx.lock().unwrap();
            rx.recv()
        };
        let Ok(batch) = batch else { return };
        let mut reqs = Vec::with_capacity(batch.len());
        let mut meta = Vec::with_capacity(batch.len());
        for q in batch {
            meta.push((q.submitted, q.req.phase, q.req.output_len));
            reqs.push(q.req);
        }
        let slots = OpenSlots { open: &open, n: reqs.len() };
        match server.serve_batch(&reqs, policy) {
            Ok((responses, _stats)) => {
                for (mut resp, (submitted, phase, output_len)) in
                    responses.into_iter().zip(meta)
                {
                    if output_len > 0 {
                        // Autoregressive re-entry: this pass's output is
                        // the next step's input, the KV cache grows by
                        // the entry this pass wrote. The re-entry keeps
                        // the request open: add its slot before the
                        // batch guard releases this pass's.
                        let next = EmbeddedRequest {
                            id: resp.id,
                            hidden: resp.hidden,
                            phase: Phase::Decode { kv_len: phase.next_kv_len(prompt_len) },
                            output_len: output_len - 1,
                        };
                        server.metrics.inc("decode_steps", 1);
                        open.fetch_add(1, Ordering::SeqCst);
                        if decode_tx
                            .send(QueuedRequest {
                                req: next,
                                enqueued: Instant::now(),
                                submitted,
                            })
                            .is_err()
                        {
                            // Assembler gone mid-shutdown: the request
                            // can never finish, release its slot.
                            open.fetch_sub(1, Ordering::SeqCst);
                        }
                        continue;
                    }
                    resp.latency_s = submitted.elapsed().as_secs_f64();
                    server.metrics.observe("request_latency", resp.latency_s);
                    if resp_tx.send(resp).is_err() {
                        return; // guard releases the batch's slots
                    }
                }
            }
            Err(e) => {
                // Drop the batch but keep the replica alive; callers
                // see the gap via the serve_errors counter. Every
                // request of the failed batch is done for (the guard
                // releases their slots).
                server.metrics.inc("serve_errors", 1);
                eprintln!("serving worker: batch failed: {e:#}");
            }
        }
        drop(slots);
    }
}
