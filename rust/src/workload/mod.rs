//! Synthetic serving workloads.
//!
//! * Offline mode (Table 5): fixed-size batches at a fixed sequence
//!   length — the throughput-saturation regime.
//! * Online mode (Table 6 / §5.5): requests arrive with unpredictable
//!   prompt lengths; batches form per arrival window and the scheduler
//!   re-solves per batch. Scenarios are parameterized by the *mean
//!   arriving token count* (the paper uses 3072 and 6144).
//! * Decode mode (MegaScale-Infer's steady state): each request also
//!   samples an *output length*; after its prompt prefills, the request
//!   re-enters the stream as autoregressive decode steps — one token
//!   per step, KV cache growing by one entry each time
//!   ([`crate::config::Phase::next_kv_len`] is the shared growth rule;
//!   [`Request::next_decode_step`] applies it to workload requests the
//!   way the coordinator's batcher applies it to embedded ones).

use crate::config::{ExpertLoad, LoadProfile, Phase};
use crate::util::rng::Rng;

/// One inference request (or one autoregressive step of one — the
/// phase distinguishes them).
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Prompt sequence length.
    pub seq_len: usize,
    /// Arrival time, seconds from epoch start.
    pub arrival_s: f64,
    /// Prefill (process the whole prompt) or one decode step against a
    /// grown KV cache.
    pub phase: Phase,
    /// Decode steps still to run after this pass completes (the
    /// remaining sampled output length); 0 = this pass is the last.
    pub output_len: usize,
}

impl Request {
    /// A plain prefill-only request (no decode re-entry).
    pub fn prefill(id: u64, seq_len: usize, arrival_s: f64) -> Self {
        Self { id, seq_len, arrival_s, phase: Phase::Prefill, output_len: 0 }
    }

    /// Tokens this pass contributes: the prompt for prefill, one
    /// generated token for a decode step.
    pub fn tokens(&self) -> usize {
        self.phase.tokens_per_sample(self.seq_len)
    }

    /// KV entries resident while this pass executes.
    pub fn kv_resident(&self) -> usize {
        self.phase.kv_resident(self.seq_len)
    }

    /// The decode step that follows this pass, its KV grown by the
    /// entry this pass wrote ([`Phase::next_kv_len`]) — or `None` when
    /// the sampled output is exhausted.
    pub fn next_decode_step(&self) -> Option<Request> {
        if self.output_len == 0 {
            return None;
        }
        Some(Request {
            phase: Phase::Decode { kv_len: self.phase.next_kv_len(self.seq_len) },
            output_len: self.output_len - 1,
            ..self.clone()
        })
    }
}

/// Offline batch generator: `count` requests of identical length.
pub fn offline_batch(count: usize, seq_len: usize) -> Vec<Request> {
    (0..count).map(|i| Request::prefill(i as u64, seq_len, 0.0)).collect()
}

/// Synthetic gating behaviour: which experts a request's tokens route
/// to, layer by layer. Expert popularity is Zipf-shaped (rank `e` gets
/// weight `(e+1)^(-zipf_s/temp)` — `zipf_s = 0` is exactly uniform
/// traffic), and `layer_corr` is the probability that a request keeps
/// its previous layer's expert instead of re-sampling — the cross-layer
/// stickiness real MoE traces show, which makes skew persistent across
/// a request's whole forward pass rather than averaging out.
#[derive(Debug, Clone)]
pub struct GatingProfile {
    pub n_experts: usize,
    /// Zipf exponent of expert popularity (0 = uniform).
    pub zipf_s: f64,
    /// Temperature flattening: the effective exponent is
    /// `zipf_s / temp`, so `temp > 1` pulls traffic toward uniform.
    pub temp: f64,
    /// Probability a request re-uses its previous layer's expert.
    pub layer_corr: f64,
}

impl GatingProfile {
    /// Balanced traffic (the legacy uniform-expert assumption).
    pub fn uniform(n_experts: usize) -> Self {
        Self { n_experts, zipf_s: 0.0, temp: 1.0, layer_corr: 0.0 }
    }

    /// Skewed traffic with the cross-layer stickiness of real traces.
    pub fn skewed(n_experts: usize, zipf_s: f64) -> Self {
        Self { n_experts, zipf_s, temp: 1.0, layer_corr: 0.6 }
    }

    /// Effective Zipf exponent after temperature flattening.
    fn s_eff(&self) -> f64 {
        assert!(self.temp > 0.0, "non-positive gating temperature");
        self.zipf_s / self.temp
    }

    /// The marginal per-expert relative load this profile induces —
    /// what the planner prices placements against. Cross-layer
    /// correlation does not move the marginal (a re-used expert was
    /// itself drawn from the same Zipf), so this is the plain
    /// [`LoadProfile`] load.
    pub fn expert_load(&self) -> ExpertLoad {
        if self.zipf_s == 0.0 {
            LoadProfile::Uniform.load(self.n_experts)
        } else {
            LoadProfile::Zipf { s: self.zipf_s, temp: self.temp }.load(self.n_experts)
        }
    }

    /// Sample one request's expert choice per layer: Zipf-popular
    /// experts, re-used from the previous layer with probability
    /// `layer_corr`. Seeded and deterministic via `rng`.
    pub fn sample_request(&self, n_layers: usize, rng: &mut Rng) -> Vec<usize> {
        let s = self.s_eff();
        let mut out = Vec::with_capacity(n_layers);
        let mut prev: Option<usize> = None;
        for _ in 0..n_layers {
            let e = match prev {
                Some(p) if rng.f64() < self.layer_corr => p,
                _ => rng.zipf(self.n_experts, s),
            };
            out.push(e);
            prev = Some(e);
        }
        out
    }
}

/// Online arrival process: Poisson arrivals at `rate_per_s`, lognormal
/// prompt lengths with the given mean/std, rounded up to a multiple of
/// `round_to` (shape buckets) and truncated to [min_len, max_len] —
/// never above `max_len`, so every emitted length fits a compiled
/// bucket.
#[derive(Debug, Clone)]
pub struct OnlineWorkload {
    pub rate_per_s: f64,
    pub mean_len: f64,
    pub std_len: f64,
    pub min_len: usize,
    pub max_len: usize,
    pub round_to: usize,
}

impl OnlineWorkload {
    /// The paper's Table-6 scenario: mean arriving tokens per request.
    pub fn paper_scenario(mean_tokens: usize) -> Self {
        Self {
            rate_per_s: 4.0,
            mean_len: mean_tokens as f64,
            std_len: mean_tokens as f64 * 0.4,
            min_len: 256,
            max_len: 4 * mean_tokens,
            round_to: 256,
        }
    }

    /// Generate `n` requests.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<Request> {
        let mut t = 0.0;
        (0..n)
            .map(|i| {
                t += rng.exponential(self.rate_per_s);
                let len = self.sample_len(rng);
                Request::prefill(i as u64, len, t)
            })
            .collect()
    }

    /// One bucketed, bounded length. Rounding happens *before* the
    /// clamp, and the clamp itself runs on the grid points inside
    /// [min_len, max_len] (min rounded up, max rounded down), so an
    /// emitted length is always a `round_to` multiple and never above
    /// `max_len` — the old clamp-then-round order emitted
    /// `max_len + round_to` whenever `max_len` was off the bucket grid,
    /// overflowing the largest compiled attention bucket. Bounds so
    /// tight that no grid point lies between them fall back to
    /// `max_len` itself (bounded beats bucketed).
    fn sample_len(&self, rng: &mut Rng) -> usize {
        let raw = rng.lognormal_mean_std(self.mean_len, self.std_len);
        let bucketed = (raw as usize).max(1).div_ceil(self.round_to) * self.round_to;
        let grid_min = self.min_len.div_ceil(self.round_to) * self.round_to;
        let grid_max = (self.max_len / self.round_to) * self.round_to;
        if grid_min > grid_max {
            return self.max_len;
        }
        bucketed.clamp(grid_min, grid_max)
    }
}

/// Autoregressive serving workload: online prompt arrivals plus a
/// lognormal *output length* per request. A generated request starts as
/// a prefill pass carrying `output_len` pending decode steps; walking
/// [`Request::next_decode_step`] yields the KV-growing step sequence.
#[derive(Debug, Clone)]
pub struct DecodeWorkload {
    pub prompt: OnlineWorkload,
    pub mean_output: f64,
    pub std_output: f64,
    pub min_output: usize,
    pub max_output: usize,
}

impl DecodeWorkload {
    /// Decode scenario over a Table-6 prompt distribution: mean output
    /// 256 tokens, spread 0.5×, bounded to [16, 1024].
    pub fn paper_scenario(mean_prompt_tokens: usize) -> Self {
        Self {
            prompt: OnlineWorkload::paper_scenario(mean_prompt_tokens),
            mean_output: 256.0,
            std_output: 128.0,
            min_output: 16,
            max_output: 1024,
        }
    }

    /// Generate `n` requests (prefill passes with sampled pending
    /// output lengths, Poisson arrivals from the prompt process).
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Vec<Request> {
        let mut reqs = self.prompt.generate(n, rng);
        for r in &mut reqs {
            let raw = rng.lognormal_mean_std(self.mean_output, self.std_output);
            r.output_len = (raw as usize).clamp(self.min_output, self.max_output);
        }
        reqs
    }
}

/// All passes of one request, prefill first then every KV-growing
/// decode step (`1 + output_len` entries).
pub fn decode_steps(req: &Request) -> Vec<Request> {
    let mut out = vec![req.clone()];
    while let Some(next) = out.last().unwrap().next_decode_step() {
        out.push(next);
    }
    out
}

/// Split a mixed batch into its prefill and decode sub-batches,
/// preserving order within each class — the shape the coordinator
/// schedules under two separate cached plans.
pub fn split_phases(batch: &[Request]) -> (Vec<Request>, Vec<Request>) {
    let (mut pre, mut dec) = (Vec::new(), Vec::new());
    for r in batch {
        if r.phase.is_decode() {
            dec.push(r.clone());
        } else {
            pre.push(r.clone());
        }
    }
    (pre, dec)
}

/// Group online requests into serving batches: consecutive arrivals
/// within `window_s` of the batch head, up to `max_batch` requests,
/// bucketed by rounded sequence length so one AOT artifact shape serves
/// the whole batch.
pub fn window_batches(reqs: &[Request], window_s: f64, max_batch: usize) -> Vec<Vec<Request>> {
    let mut batches: Vec<Vec<Request>> = Vec::new();
    let mut current: Vec<Request> = Vec::new();
    let mut head_t = f64::NEG_INFINITY;
    for r in reqs {
        let fits_window = current.is_empty() || r.arrival_s - head_t <= window_s;
        if current.is_empty() {
            head_t = r.arrival_s;
        }
        if !fits_window || current.len() >= max_batch {
            batches.push(std::mem::take(&mut current));
            head_t = r.arrival_s;
        }
        current.push(r.clone());
    }
    if !current.is_empty() {
        batches.push(current);
    }
    batches
}

/// Representative sequence length for a batch: the max (padding model —
/// every sample is padded up to the bucket the artifact was compiled
/// for). The solve boundary: an empty batch has no shape and must be
/// skipped by the caller before planning, never solved as `S = 0`.
pub fn batch_seq_len(batch: &[Request]) -> usize {
    assert!(!batch.is_empty(), "empty batch reached planning; skip it upstream");
    batch.iter().map(|r| r.seq_len).max().unwrap()
}

/// Representative KV length for a decode sub-batch: the max resident
/// KV (padding model). Same non-empty contract as [`batch_seq_len`].
pub fn batch_kv_len(batch: &[Request]) -> usize {
    assert!(!batch.is_empty(), "empty batch reached planning; skip it upstream");
    batch.iter().map(|r| r.kv_resident()).max().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offline_batches_are_uniform() {
        let b = offline_batch(16, 2048);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|r| r.seq_len == 2048 && r.arrival_s == 0.0));
        assert_eq!(b[3].tokens(), 2048);
        assert!(b.iter().all(|r| r.phase == Phase::Prefill && r.output_len == 0));
    }

    #[test]
    fn gating_profile_marginal_and_correlation() {
        // Uniform profile: exactly the legacy assumption.
        let flat = GatingProfile::uniform(16);
        assert!(flat.expert_load().is_uniform());
        // Skewed profile: marginal matches the empirical expert
        // frequency of many sampled requests.
        let prof = GatingProfile::skewed(16, 1.2);
        let load = prof.expert_load();
        assert!(load.rel(0) > load.rel(15));
        let mut rng = Rng::new(11);
        let mut counts = vec![0usize; 16];
        let n_layers = 8;
        let draws = 4000;
        for _ in 0..draws {
            for e in prof.sample_request(n_layers, &mut rng) {
                counts[e] += 1;
            }
        }
        let total = (draws * n_layers) as f64;
        for e in [0usize, 4, 15] {
            let emp = counts[e] as f64 / total * 16.0;
            assert!(
                (emp - load.rel(e)).abs() < 0.25 * load.rel(e).max(0.5),
                "expert {e}: empirical rel {emp} vs marginal {}",
                load.rel(e)
            );
        }
        // Cross-layer correlation: sticky profiles repeat the previous
        // layer's expert far more often than independent draws.
        let repeat_rate = |corr: f64, rng: &mut Rng| {
            let p = GatingProfile { layer_corr: corr, ..prof.clone() };
            let (mut rep, mut tot) = (0usize, 0usize);
            for _ in 0..2000 {
                let picks = p.sample_request(n_layers, rng);
                for w in picks.windows(2) {
                    tot += 1;
                    rep += (w[0] == w[1]) as usize;
                }
            }
            rep as f64 / tot as f64
        };
        let mut rng = Rng::new(12);
        let sticky = repeat_rate(0.9, &mut rng);
        let indep = repeat_rate(0.0, &mut rng);
        assert!(sticky > indep + 0.3, "sticky {sticky} vs independent {indep}");
        // Seeded determinism.
        let a = prof.sample_request(32, &mut Rng::new(77));
        let b = prof.sample_request(32, &mut Rng::new(77));
        assert_eq!(a, b);
    }

    #[test]
    fn online_lengths_bucketed_and_bounded() {
        let w = OnlineWorkload::paper_scenario(3072);
        let mut rng = Rng::new(1);
        let reqs = w.generate(500, &mut rng);
        assert_eq!(reqs.len(), 500);
        for r in &reqs {
            assert!(r.seq_len >= w.min_len);
            assert!(r.seq_len <= w.max_len, "len {} above max_len {}", r.seq_len, w.max_len);
            assert_eq!(r.seq_len % w.round_to, 0);
        }
        // Arrivals strictly increase.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        // Mean length lands near the target.
        let mean: f64 =
            reqs.iter().map(|r| r.seq_len as f64).sum::<f64>() / reqs.len() as f64;
        assert!((mean - 3072.0).abs() / 3072.0 < 0.2, "mean={mean}");
    }

    #[test]
    fn off_grid_bounds_still_emit_bucketed_lengths() {
        // min_len off the grid: the lower clamp rounds up to the next
        // grid point instead of emitting an off-bucket 300.
        let w = OnlineWorkload {
            rate_per_s: 4.0,
            mean_len: 600.0,
            std_len: 500.0,
            min_len: 300,
            max_len: 4096,
            round_to: 256,
        };
        let mut rng = Rng::new(21);
        for r in w.generate(500, &mut rng) {
            assert_eq!(r.seq_len % 256, 0, "len {} off the bucket grid", r.seq_len);
            assert!(r.seq_len >= 300 && r.seq_len <= 4096);
        }
        // Pathological band with no grid point inside: bounded wins.
        let tight = OnlineWorkload { min_len: 300, max_len: 400, round_to: 256, ..w };
        let mut rng = Rng::new(22);
        for r in tight.generate(100, &mut rng) {
            assert_eq!(r.seq_len, 400, "must fall back to max_len");
        }
    }

    #[test]
    fn clamp_happens_after_rounding() {
        // Regression for the bucket-overflow bug: with max_len off the
        // bucket grid and the mean pushed against it, the old
        // clamp-then-round order rounded clamped lengths up to
        // max_len + (round_to - max_len % round_to) — above max_len,
        // missing every compiled bucket. Every length must stay
        // ≤ max_len and on the bucket grid.
        let w = OnlineWorkload {
            rate_per_s: 4.0,
            mean_len: 800.0,
            std_len: 600.0,
            min_len: 256,
            max_len: 1000, // not a multiple of round_to
            round_to: 256,
        };
        let mut rng = Rng::new(9);
        let reqs = w.generate(2000, &mut rng);
        // The effective ceiling is the largest grid point under
        // max_len, and it must actually be hit.
        assert!(reqs.iter().any(|r| r.seq_len == 768), "clamp never exercised");
        for r in &reqs {
            assert!(r.seq_len <= w.max_len, "len {} overflows max_len", r.seq_len);
            assert!(r.seq_len >= w.min_len);
            assert_eq!(r.seq_len % w.round_to, 0);
        }
    }

    #[test]
    fn windows_respect_size_and_time() {
        let reqs: Vec<Request> =
            (0..10).map(|i| Request::prefill(i, 512, i as f64 * 0.1)).collect();
        let batches = window_batches(&reqs, 0.25, 3);
        assert!(batches.iter().all(|b| b.len() <= 3));
        let total: usize = batches.iter().map(|b| b.len()).sum();
        assert_eq!(total, 10);
        // A huge window with big max_batch puts everything together.
        let one = window_batches(&reqs, 100.0, 100);
        assert_eq!(one.len(), 1);
    }

    #[test]
    fn window_flushes_on_max_batch_mid_burst() {
        // 7 requests in one instantaneous burst with max_batch = 3: the
        // size cap must cut the burst into 3/3/1 in arrival order, and
        // every flush re-heads the window at the overflowing request.
        let reqs: Vec<Request> = (0..7).map(|i| Request::prefill(i, 512, 1.0)).collect();
        let batches = window_batches(&reqs, 10.0, 3);
        let sizes: Vec<usize> = batches.iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![3, 3, 1]);
        let ids: Vec<u64> = batches.iter().flatten().map(|r| r.id).collect();
        assert_eq!(ids, (0..7).collect::<Vec<_>>(), "flush reordered the burst");
    }

    #[test]
    fn window_boundary_arrival_is_inclusive() {
        // A request arriving exactly window_s after the batch head
        // satisfies `arrival - head <= window_s` and joins the batch;
        // one epsilon later starts a new batch.
        let exact = vec![
            Request::prefill(0, 512, 1.0),
            Request::prefill(1, 512, 1.5), // == head + window_s
        ];
        assert_eq!(window_batches(&exact, 0.5, 10).len(), 1);
        let beyond = vec![
            Request::prefill(0, 512, 1.0),
            Request::prefill(1, 512, 1.5 + 1e-9),
        ];
        assert_eq!(window_batches(&beyond, 0.5, 10).len(), 2);
        // The boundary is measured from the batch *head*, not the
        // previous request: two in-window arrivals don't extend it.
        let chain = vec![
            Request::prefill(0, 512, 1.0),
            Request::prefill(1, 512, 1.4),
            Request::prefill(2, 512, 1.8), // 0.4 after prev, 0.8 after head
        ];
        assert_eq!(window_batches(&chain, 0.5, 10).len(), 2);
    }

    #[test]
    fn batch_seq_len_is_max() {
        let b = vec![Request::prefill(0, 512, 0.0), Request::prefill(1, 1024, 0.1)];
        assert_eq!(batch_seq_len(&b), 1024);
    }

    #[test]
    #[should_panic(expected = "empty batch")]
    fn batch_seq_len_rejects_empty_batches() {
        let _ = batch_seq_len(&[]);
    }

    #[test]
    fn decode_steps_grow_kv_one_token_at_a_time() {
        let mut req = Request::prefill(7, 2048, 0.5);
        req.output_len = 3;
        let steps = decode_steps(&req);
        assert_eq!(steps.len(), 4, "prefill + output_len decode steps");
        assert_eq!(steps[0].phase, Phase::Prefill);
        assert_eq!(steps[0].tokens(), 2048);
        // Step t reads the prompt plus the t-1 tokens generated so far.
        for (t, s) in steps[1..].iter().enumerate() {
            assert_eq!(s.phase, Phase::Decode { kv_len: 2048 + t });
            assert_eq!(s.tokens(), 1);
            assert_eq!(s.kv_resident(), 2048 + t + 1);
            assert_eq!(s.id, 7);
        }
        assert_eq!(steps[3].output_len, 0);
        assert!(steps[3].next_decode_step().is_none());
    }

    #[test]
    fn decode_workload_samples_bounded_outputs() {
        let w = DecodeWorkload::paper_scenario(3072);
        let mut rng = Rng::new(3);
        let reqs = w.generate(300, &mut rng);
        for r in &reqs {
            assert_eq!(r.phase, Phase::Prefill, "requests enter as prefill");
            assert!(r.output_len >= w.min_output && r.output_len <= w.max_output);
            assert!(r.seq_len <= w.prompt.max_len);
        }
        // Outputs vary (it is a distribution, not a constant)...
        let first = reqs[0].output_len;
        assert!(reqs.iter().any(|r| r.output_len != first));
        // ...with the mean near the target.
        let mean: f64 =
            reqs.iter().map(|r| r.output_len as f64).sum::<f64>() / reqs.len() as f64;
        assert!((mean - 256.0).abs() / 256.0 < 0.25, "mean output {mean}");
    }

    #[test]
    fn split_phases_preserves_order_within_class() {
        let mut batch = Vec::new();
        for i in 0..6u64 {
            let mut r = Request::prefill(i, 512, 0.0);
            if i % 2 == 0 {
                r.phase = Phase::Decode { kv_len: 512 + i as usize };
            }
            batch.push(r);
        }
        let (pre, dec) = split_phases(&batch);
        assert_eq!(pre.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 3, 5]);
        assert_eq!(dec.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert_eq!(batch_kv_len(&dec), 512 + 4 + 1);
    }
}
