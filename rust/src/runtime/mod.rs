//! PJRT runtime: loads the AOT HLO artifacts produced by
//! `python/compile/aot.py`, compiles them once on the PJRT CPU client,
//! and executes them from the serving hot path. Python never runs here.

pub mod artifact;
pub mod engine;
pub mod probe;
pub mod tensor;

pub use artifact::{ArtifactSet, Manifest, Weights};
pub use engine::Engine;
pub use tensor::Tensor;

/// Default artifacts directory relative to the repo root; overridable
/// with `FINDEP_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("FINDEP_ARTIFACTS") {
        return p.into();
    }
    // Walk up from CWD to find an `artifacts/manifest.json`.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
