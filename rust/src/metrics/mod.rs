//! Serving metrics: counters, latency histograms, and throughput meters
//! used by the coordinator and the bench harnesses.
//!
//! The [`Registry`] is sharded for the event-driven coordinator's
//! worker pool: each thread binds (round-robin) to one of
//! [`N_SHARDS`] shards and writes only there — counter increments are
//! lock-free atomic adds under a shared read lock, histogram
//! observations contend only within a shard — while every read-side
//! accessor (`counter`, `histogram_*`, `snapshot_json`) merges across
//! shards on scrape. The merged output is shape-identical to the old
//! single-mutex registry, so dashboards and tests read the same JSON.

use std::cell::Cell;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError, RwLock};
use std::time::Instant;

use crate::util::json::{Json, JsonObj};
use crate::util::stats;

/// Latency histogram with fixed log-spaced buckets (1 µs .. ~100 s).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket upper bounds in seconds.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    /// Raw samples kept for exact percentiles (bounded reservoir).
    samples: Vec<f64>,
    max_samples: usize,
    total: u64,
    sum: f64,
    /// Largest observed value (0.0 when empty) — the SLO tail beyond
    /// the reservoir's percentile reach.
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        let mut bounds = Vec::new();
        let mut b = 1e-6;
        while b < 100.0 {
            bounds.push(b);
            b *= 2.0;
        }
        let n = bounds.len();
        Self {
            bounds,
            counts: vec![0; n + 1],
            samples: Vec::new(),
            max_samples: 65_536,
            total: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    pub fn record(&mut self, seconds: f64) {
        let idx = self.bounds.partition_point(|&b| b < seconds);
        self.counts[idx] += 1;
        self.total += 1;
        self.sum += seconds;
        self.max = self.max.max(seconds);
        if self.samples.len() < self.max_samples {
            self.samples.push(seconds);
        }
    }

    /// Fold another histogram (same fixed bounds — all histograms
    /// share one constructor) into this one: the scrape-side merge of
    /// the sharded registry. The sample reservoir absorbs the other's
    /// samples up to capacity; counts, sum, and max merge exactly.
    pub fn merge(&mut self, other: &Histogram) {
        for (c, o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
        let room = self.max_samples.saturating_sub(self.samples.len());
        self.samples.extend(other.samples.iter().take(room).copied());
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Largest observed value (0.0 when empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn percentile(&self, p: f64) -> f64 {
        stats::percentile(&self.samples, p)
    }

    /// Fraction of reservoir samples at or under `x` (SLO attainment:
    /// the share of requests meeting a latency target). 1.0 when empty
    /// — no sample exceeded the bound.
    pub fn fraction_le(&self, x: f64) -> f64 {
        if self.samples.is_empty() {
            return 1.0;
        }
        self.samples.iter().filter(|&&s| s <= x).count() as f64 / self.samples.len() as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("count", Json::Num(self.total as f64));
        o.insert("mean_s", Json::Num(self.mean()));
        o.insert("p50_s", Json::Num(self.percentile(50.0)));
        o.insert("p95_s", Json::Num(self.percentile(95.0)));
        o.insert("p99_s", Json::Num(self.percentile(99.0)));
        o.insert("max_s", Json::Num(self.max));
        Json::Obj(o)
    }
}

/// Tokens/s meter over a wall-clock window.
#[derive(Debug)]
pub struct ThroughputMeter {
    started: Instant,
    tokens: u64,
    requests: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        Self { started: Instant::now(), tokens: 0, requests: 0 }
    }

    pub fn add(&mut self, tokens: u64) {
        self.tokens += tokens;
        self.requests += 1;
    }

    pub fn tokens(&self) -> u64 {
        self.tokens
    }

    pub fn requests(&self) -> u64 {
        self.requests
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn tokens_per_s(&self) -> f64 {
        let e = self.elapsed_s();
        if e > 0.0 {
            self.tokens as f64 / e
        } else {
            0.0
        }
    }
}

/// Shard count: comfortably above the batcher's worker counts so
/// threads rarely share a shard, small enough that scrape-side merges
/// stay trivial.
const N_SHARDS: usize = 16;

/// Hands each thread a stable shard index, round-robin across every
/// thread that ever touches any registry (shards are per-registry;
/// only the index assignment is global).
static SHARD_SEQ: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static SHARD: Cell<usize> = const { Cell::new(usize::MAX) };
}

fn shard_index() -> usize {
    SHARD.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = SHARD_SEQ.fetch_add(1, Ordering::Relaxed) % N_SHARDS;
            s.set(v);
        }
        v
    })
}

/// One registry shard: atomic counters behind a name-map read lock
/// (the write lock is taken once per name, to create the atomic), and
/// locally-locked histograms.
#[derive(Debug, Default)]
struct Shard {
    counters: RwLock<BTreeMap<String, AtomicU64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

/// Thread-safe metrics registry shared across coordinator components,
/// sharded per worker thread and merged on scrape.
#[derive(Debug)]
pub struct Registry {
    shards: Vec<Shard>,
}

impl Default for Registry {
    fn default() -> Self {
        Self { shards: (0..N_SHARDS).map(|_| Shard::default()).collect() }
    }
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// This thread's home shard.
    fn shard(&self) -> &Shard {
        &self.shards[shard_index() % self.shards.len()]
    }

    pub fn inc(&self, name: &str, by: u64) {
        let shard = self.shard();
        {
            // Hot path: the counter exists in this shard — a shared
            // lock plus one atomic add, no exclusive section at all.
            let counters = shard.counters.read().unwrap_or_else(PoisonError::into_inner);
            if let Some(c) = counters.get(name) {
                c.fetch_add(by, Ordering::Relaxed);
                return;
            }
        }
        shard
            .counters
            .write()
            .unwrap_or_else(PoisonError::into_inner)
            .entry(name.to_string())
            .or_insert_with(|| AtomicU64::new(0))
            .fetch_add(by, Ordering::Relaxed);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.shards
            .iter()
            .map(|s| {
                s.counters
                    .read()
                    .unwrap_or_else(PoisonError::into_inner)
                    .get(name)
                    .map(|c| c.load(Ordering::Relaxed))
                    .unwrap_or(0)
            })
            .sum()
    }

    pub fn observe(&self, name: &str, seconds: f64) {
        self.shard()
            .histograms
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .entry(name.to_string())
            .or_default()
            .record(seconds);
    }

    /// Scrape-side merge of one histogram across shards (`None` when
    /// no shard ever observed it).
    fn merged_histogram(&self, name: &str) -> Option<Histogram> {
        let mut merged: Option<Histogram> = None;
        for s in &self.shards {
            let hists = s.histograms.lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(h) = hists.get(name) {
                match &mut merged {
                    Some(m) => m.merge(h),
                    None => merged = Some(h.clone()),
                }
            }
        }
        merged
    }

    pub fn histogram_json(&self, name: &str) -> Option<Json> {
        self.merged_histogram(name).map(|h| h.to_json())
    }

    /// Sample count of a histogram (0 when it was never observed).
    pub fn histogram_count(&self, name: &str) -> u64 {
        self.merged_histogram(name).map(|h| h.count()).unwrap_or(0)
    }

    /// Mean of a histogram, or `None` when no histogram of that name
    /// was ever observed — distinguishable from a true zero mean (the
    /// old 0.0 sentinel was not).
    pub fn histogram_mean(&self, name: &str) -> Option<f64> {
        self.merged_histogram(name).map(|h| h.mean())
    }

    /// Exact percentile (from the merged sample reservoir) of a
    /// histogram, or `None` when it was never observed — the SLO
    /// accessor (p99 queue wait, max request latency) the goodput
    /// items and the coordinator bench report.
    pub fn histogram_percentile(&self, name: &str, p: f64) -> Option<f64> {
        self.merged_histogram(name).map(|h| h.percentile(p))
    }

    /// Fraction of a histogram's samples at or under `x`, or `None`
    /// when it was never observed — the SLO-attainment accessor
    /// (share of requests whose TTFT/TPOT met its target).
    pub fn histogram_fraction_le(&self, name: &str, x: f64) -> Option<f64> {
        self.merged_histogram(name).map(|h| h.fraction_le(x))
    }

    /// Largest observed value of a histogram, or `None` when it was
    /// never observed.
    pub fn histogram_max(&self, name: &str) -> Option<f64> {
        self.merged_histogram(name).map(|h| h.max())
    }

    pub fn snapshot_json(&self) -> Json {
        let mut o = JsonObj::new();
        let mut merged_counters: BTreeMap<String, u64> = BTreeMap::new();
        for s in &self.shards {
            for (k, v) in s.counters.read().unwrap_or_else(PoisonError::into_inner).iter() {
                *merged_counters.entry(k.clone()).or_insert(0) += v.load(Ordering::Relaxed);
            }
        }
        let mut counters = JsonObj::new();
        for (k, v) in &merged_counters {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        o.insert("counters", Json::Obj(counters));
        let mut names: BTreeSet<String> = BTreeSet::new();
        for s in &self.shards {
            names.extend(
                s.histograms.lock().unwrap_or_else(PoisonError::into_inner).keys().cloned(),
            );
        }
        let mut hists = JsonObj::new();
        for k in &names {
            if let Some(h) = self.merged_histogram(k) {
                hists.insert(k.clone(), h.to_json());
            }
        }
        o.insert("histograms", Json::Obj(hists));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 100);
        assert!((h.mean() - 0.0505).abs() < 1e-6);
        assert!((h.percentile(50.0) - 0.0505).abs() < 2e-3);
        assert!(h.percentile(99.0) > 0.09);
        assert!((h.max() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn histogram_merge_is_exact_for_counts_sum_and_max() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for i in 1..=50 {
            a.record(i as f64 * 1e-3);
        }
        for i in 51..=100 {
            b.record(i as f64 * 1e-3);
        }
        a.merge(&b);
        let mut whole = Histogram::new();
        for i in 1..=100 {
            whole.record(i as f64 * 1e-3);
        }
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.max() - whole.max()).abs() < 1e-12);
        assert!((a.percentile(99.0) - whole.percentile(99.0)).abs() < 1e-9);
        // Empty-into-full and full-into-empty both behave.
        let mut empty = Histogram::new();
        empty.merge(&whole);
        assert_eq!(empty.count(), 100);
        let before = whole.count();
        whole.merge(&Histogram::new());
        assert_eq!(whole.count(), before);
    }

    #[test]
    fn histogram_json_reports_tail_fields() {
        let mut h = Histogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-3);
        }
        let j = h.to_json();
        assert_eq!(j.get("count").as_f64(), Some(100.0));
        assert!(j.get("p99_s").as_f64().unwrap() > 0.09);
        assert!((j.get("max_s").as_f64().unwrap() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn meter_accumulates() {
        let mut m = ThroughputMeter::new();
        m.add(100);
        m.add(200);
        assert_eq!(m.tokens(), 300);
        assert_eq!(m.requests(), 2);
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(m.tokens_per_s() > 0.0);
    }

    #[test]
    fn registry_is_shared_safely() {
        let r = std::sync::Arc::new(Registry::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    r.inc("reqs", 1);
                    r.observe("lat", 0.001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(r.counter("reqs"), 400);
        let j = r.snapshot_json();
        assert_eq!(j.get("counters").get("reqs").as_f64(), Some(400.0));
        assert!(r.histogram_json("lat").is_some());
        assert!(r.histogram_json("missing").is_none());
        assert_eq!(r.histogram_count("lat"), 400);
        assert!((r.histogram_mean("lat").unwrap() - 0.001).abs() < 1e-9);
        assert_eq!(r.histogram_count("missing"), 0);
        // An unknown histogram is None, not a fake zero mean; a real
        // all-zero histogram still reads back as Some(0.0).
        assert_eq!(r.histogram_mean("missing"), None);
        r.observe("zero", 0.0);
        assert_eq!(r.histogram_mean("zero"), Some(0.0));
    }

    #[test]
    fn sharded_writes_merge_exactly_on_scrape() {
        // More threads than shards: increments and observations land
        // across many shards (and some shared ones) yet every scrape
        // accessor reads the exact merged totals.
        let r = std::sync::Arc::new(Registry::new());
        let threads = 24usize;
        let per = 50u64;
        let mut handles = Vec::new();
        for t in 0..threads {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    r.inc("ops", 1);
                    r.inc("bytes", 10);
                    r.observe("wait", (t as f64 + 1.0) * 1e-4 + i as f64 * 1e-9);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = threads as u64 * per;
        assert_eq!(r.counter("ops"), total);
        assert_eq!(r.counter("bytes"), total * 10);
        assert_eq!(r.histogram_count("wait"), total);
        // The slowest thread's largest observation survives the merge.
        let expect_max = threads as f64 * 1e-4 + (per - 1) as f64 * 1e-9;
        assert!((r.histogram_max("wait").unwrap() - expect_max).abs() < 1e-12);
        // Percentile accessor reads the merged reservoir.
        let p99 = r.histogram_percentile("wait", 99.0).unwrap();
        assert!(p99 > r.histogram_percentile("wait", 50.0).unwrap());
        assert!(p99 <= expect_max + 1e-12);
        assert_eq!(r.histogram_percentile("missing", 99.0), None);
        let j = r.snapshot_json();
        assert_eq!(j.get("counters").get("ops").as_f64(), Some(total as f64));
        assert_eq!(j.get("histograms").get("wait").get("count").as_f64(), Some(total as f64));
        assert!(j.get("histograms").get("wait").get("max_s").as_f64().is_some());
    }
}
