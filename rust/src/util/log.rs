//! Minimal leveled stderr logger (no `env_logger` in the vendored set).
//!
//! Level is controlled by `FINDEP_LOG` (error|warn|info|debug|trace);
//! default `info`. Timestamps are monotonic seconds since process start,
//! which is what you want when reading scheduler traces.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn from_env() -> Level {
        match std::env::var("FINDEP_LOG").unwrap_or_default().to_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised
static START: OnceLock<Instant> = OnceLock::new();

fn current_level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let lvl = Level::from_env();
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        lvl
    } else {
        // Safety: only valid discriminants are ever stored.
        unsafe { std::mem::transmute::<u8, Level>(raw) }
    }
}

/// Override the level programmatically (tests, CLI --verbose).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    level <= current_level()
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        eprintln!("[{t:>10.4} {} {module}] {msg}", level.tag());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
