//! The α-β linear time model (Eqs. 7-9): `t(x) = α + β·x`, with α the
//! fixed launch/startup overhead and β the per-unit marginal cost.

use crate::util::stats::{self, LinFit};

/// `t(x) = alpha + beta * x`, times in seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearModel {
    pub alpha: f64,
    pub beta: f64,
}

impl LinearModel {
    pub fn new(alpha: f64, beta: f64) -> Self {
        assert!(alpha >= 0.0 && beta >= 0.0, "negative cost model");
        Self { alpha, beta }
    }

    /// Evaluate at workload `x` (x <= 0 still pays the launch cost once
    /// invoked; callers skip zero-size tasks entirely instead).
    pub fn eval(&self, x: f64) -> f64 {
        self.alpha + self.beta * x.max(0.0)
    }

    /// Least-squares fit from (workload, seconds) samples, clamping a
    /// (noise-induced) negative intercept to zero so the model stays a
    /// valid cost function. Returns the model and the fit's R².
    pub fn fit(x: &[f64], y: &[f64]) -> (Self, f64) {
        let LinFit { alpha, beta, r2 } = stats::linear_fit(x, y);
        (Self { alpha: alpha.max(0.0), beta: beta.max(0.0) }, r2)
    }

    /// Scale the marginal cost (e.g. derive β_s = 3·N_shared·β_gm·S·M·H
    /// style compositions) keeping α.
    pub fn with_beta_scaled(&self, k: f64) -> Self {
        Self { alpha: self.alpha, beta: self.beta * k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_is_affine() {
        let m = LinearModel::new(1.0, 2.0);
        assert_eq!(m.eval(0.0), 1.0);
        assert_eq!(m.eval(3.0), 7.0);
        assert_eq!(m.eval(-5.0), 1.0, "negative workloads clamp to launch cost");
    }

    #[test]
    fn fit_recovers_exact_model() {
        let x: Vec<f64> = (1..50).map(|i| i as f64 * 10.0).collect();
        let y: Vec<f64> = x.iter().map(|v| 0.25 + 0.01 * v).collect();
        let (m, r2) = LinearModel::fit(&x, &y);
        assert!((m.alpha - 0.25).abs() < 1e-9);
        assert!((m.beta - 0.01).abs() < 1e-12);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn fit_clamps_negative_intercept() {
        // Points through the origin with negative-intercept noise.
        let x = [1.0, 2.0, 3.0];
        let y = [0.9, 2.05, 3.0];
        let (m, _) = LinearModel::fit(&x, &y);
        assert!(m.alpha >= 0.0);
    }

    #[test]
    #[should_panic]
    fn negative_model_rejected() {
        LinearModel::new(-1.0, 0.0);
    }
}
