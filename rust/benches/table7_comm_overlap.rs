//! Table 7 — non-overlapped communication time for naive DEP, PPPipe,
//! and FinDEP (DeepSeek-V2 on testbed A, S ∈ {1024, 2048, 4096}).
//!
//! "Non-overlapped" = wall time where a link is transferring while both
//! compute groups sit idle (the communication the schedule failed to
//! hide). Regenerated from the simulator traces of each scheduler's
//! best configuration.
//!
//! Run: `cargo bench --bench table7_comm_overlap`

use findep::baselines::{best_naive, best_pppipe};
use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::sched::Plan;
use findep::simulator::{simulate, ScheduleTrace};
use findep::solver::{solve, Instance, SolverParams};
use findep::util::bench::Table;

fn main() {
    let params = SolverParams::default();
    let tb = Testbed::a();
    let model = ModelConfig::deepseek_v2(8); // testbed-A config (§5.4)
    let split = GroupSplit::new(3, 5);

    let mut table = Table::new(
        "Table 7: non-overlapped communication time (ms), DeepSeek-V2 on testbed A",
        &["S", "Naive-DEP", "PPPipe", "FinDEP", "ordering ok?"],
    );
    for s in [4096usize, 2048, 1024] {
        let inst = Instance::new(model.clone(), tb.clone(), split, s);
        let exposed_ms = |cfg: findep::sched::PlanConfig| -> f64 {
            let sm = inst.stage_models();
            let plan = Plan::build(&sm, cfg, model.n_layers, split.ag, s);
            let sim = simulate(&plan);
            ScheduleTrace::from_sim(&plan, &sim).non_overlapped_comm() * 1e3
        };
        let nv = best_naive(&inst, params.ma_cap).expect("naive feasible");
        let pp = best_pppipe(&inst, &params).expect("pppipe feasible");
        let fd = solve(&inst, &params).expect("findep feasible");
        let (e_nv, e_pp, e_fd) =
            (exposed_ms(nv.config), exposed_ms(pp.config), exposed_ms(fd.config));
        let ok = e_nv >= e_pp - 1e-6 && e_pp >= e_fd - 1e-6;
        table.row(&[
            s.to_string(),
            format!("{e_nv:.2}"),
            format!("{e_pp:.2}"),
            format!("{e_fd:.2}"),
            if ok { "yes".into() } else { "NO — VIOLATION".into() },
        ]);
        assert!(ok, "exposure ordering violated at S={s}");
    }
    table.print();
    println!(
        "paper Table 7 (ms): S=4096: 905.49 / 528.94 / 309.81; S=2048: 536.22 / 144.32 / 52.60; \
         S=1024: 194.95 / 188.65 / 97.33. The ordering naive > PPPipe > FinDEP and the shrinking \
         exposure with better scheduling are the reproduced shape; FinDEP reduces exposed \
         communication by >1.7x vs PPPipe at the comm-heavy points, as §5.4's discussion reports."
    );
}
