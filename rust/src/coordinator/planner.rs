//! The **planning** half of the event-driven coordinator (the
//! planner/executor phase split): batch assembly, linger-window
//! accounting, decode re-entry scheduling, and the drain state machine,
//! as one pure synchronous state machine with no threads, locks, or
//! clocks of its own.
//!
//! The planner is advanced by [`poll`](Planner::poll) under the
//! executor's lock: events (a submit, a decode re-entry, a linger
//! expiry, shutdown) mutate the queues, and `poll` answers the only
//! question the executor asks — *is a batch's dependency satisfied?*
//! The dependency edges are exactly the serving DAG's:
//!
//! * **window-full** or **linger-expiry** unlocks batch assembly
//!   (a sealed window moves to the ready side as an executable batch);
//! * **prefill-done** unlocks that request's decode step (the executor
//!   re-enters it through the decode lane, which outranks fresh
//!   submissions — finish what is in flight);
//! * **submit-close + zero open requests** unlocks worker exit
//!   (drain-on-shutdown: pending decode loops always finish first).
//!
//! Keeping this half pure makes the FIFO/linger/drain semantics
//! directly unit-testable with fabricated clocks (see the tests below)
//! — the executor only adds parking and wakeups on top.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::server::EmbeddedRequest;

/// A request plus its timestamps: `enqueued` is when *this entry*
/// joined the stream (the queue-wait reference — a decode step's wait
/// counts from its re-entry), `submitted` is the original client
/// submission (the end-to-end latency reference for the final
/// response).
#[derive(Debug)]
pub struct QueuedRequest {
    pub req: EmbeddedRequest,
    pub enqueued: Instant,
    pub submitted: Instant,
    /// Serve attempts already consumed by this entry (0 for fresh work;
    /// bumped when a replica failure re-enqueues it through the retry
    /// lane).
    pub attempts: u32,
}

impl QueuedRequest {
    /// A fresh client submission: both timestamps are now.
    pub fn fresh(req: EmbeddedRequest) -> Self {
        let now = Instant::now();
        Self { req, enqueued: now, submitted: now, attempts: 0 }
    }

    /// A decode re-entry: the queue-wait clock restarts, the
    /// end-to-end latency reference is inherited from the original
    /// submission. The retry budget resets — each decode step is a new
    /// unit of work.
    pub fn reentry(req: EmbeddedRequest, submitted: Instant) -> Self {
        Self { req, enqueued: Instant::now(), submitted, attempts: 0 }
    }

    /// A retry after a failed serve: latency reference inherited,
    /// queue-wait clock restarted, attempt counter carried forward.
    pub fn retry(req: EmbeddedRequest, submitted: Instant, attempts: u32) -> Self {
        Self { req, enqueued: Instant::now(), submitted, attempts }
    }
}

/// Planner knobs (the assembly-relevant subset of `BatcherConfig`).
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    /// Most requests per assembled batch.
    pub max_batch: usize,
    /// How long an unfilled window waits for more arrivals after its
    /// first request.
    pub linger: Duration,
    /// Bounded submit-queue depth (fresh submissions beyond it are
    /// backpressured; the decode lane is never bounded).
    pub queue_depth: usize,
}

/// What the executor should do next, as decided by one `poll`.
#[derive(Debug)]
pub enum Step {
    /// A batch's dependency is satisfied (window full, linger expired,
    /// or the shutdown drain sealed it) — execute it.
    Execute(Vec<QueuedRequest>),
    /// Nothing can happen until an event arrives: park indefinitely.
    Park,
    /// An open window is lingering: park until its deadline (an event
    /// may still arrive and fill it earlier).
    ParkUntil(Instant),
    /// Closed and fully drained — the worker may exit.
    Exit,
}

/// One `poll` outcome: the step to take plus how many bounded-queue
/// slots the poll freed (the executor turns `freed > 0` into a
/// backpressure wakeup for blocked submitters).
#[derive(Debug)]
pub struct Poll {
    pub step: Step,
    pub freed: usize,
}

/// Batch-assembly state machine. All methods are synchronous and
/// non-blocking; the executor serializes access behind its mutex.
#[derive(Debug)]
pub struct Planner {
    cfg: PlannerConfig,
    /// Fresh client submissions, FIFO, bounded by `queue_depth`.
    submits: VecDeque<QueuedRequest>,
    /// Decode re-entries, FIFO, unbounded on purpose — a worker must
    /// never block re-entering its own output (that cycle would
    /// deadlock the pool); depth is bounded anyway by the requests
    /// already admitted.
    decodes: VecDeque<QueuedRequest>,
    /// Retries of requests whose replica failed mid-serve. The highest
    /// priority lane — these requests have already waited a full queue
    /// pass plus a failed serve, so they go to the front of the next
    /// window. Unbounded for the same reason as the decode lane (pushed
    /// by workers) and similarly bounded in practice by admitted work.
    retries: VecDeque<QueuedRequest>,
    /// The window being assembled, in arrival order.
    window: Vec<QueuedRequest>,
    /// Linger deadline of the open window (set when its first request
    /// arrived; `None` iff the window is empty).
    deadline: Option<Instant>,
    closed: bool,
}

/// Outcome of offering a fresh submission to the bounded queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitOutcome {
    Accepted,
    /// Queue at `queue_depth` — backpressure.
    Full,
    /// Shutdown has begun; no new work is admitted.
    Closed,
}

/// Typed submission failure, surfaced by `Batcher::submit` and
/// `EventCore::submit` so callers can branch on the cause instead of
/// string-matching an `anyhow` message.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SubmitError {
    /// `close()` was called; no new work is admitted.
    Closed,
    /// Bounded queue at `queue_depth` (non-blocking `try_submit` only —
    /// blocking `submit` waits this state out).
    QueueFull,
    /// Every worker thread has exited (e.g. panicked) while submitters
    /// were blocked on backpressure — the queue would never drain.
    WorkersGone,
    /// Admission control: the estimated queue wait already exceeds the
    /// request's deadline, so serving it would only waste capacity.
    Shed {
        /// The wait estimate (seconds) that triggered the shed.
        estimated_wait_s: f64,
    },
    /// Malformed request rejected at the submission boundary (wrong
    /// hidden-state element count), before it could sink a whole
    /// assembled batch inside a worker.
    Invalid {
        /// The rejected request's id.
        id: u64,
        /// Element count the request carried.
        elems: usize,
        /// Element count the model expects (`S·M`).
        expected: usize,
    },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Closed => write!(f, "batcher closed"),
            SubmitError::QueueFull => write!(f, "submit queue full"),
            SubmitError::WorkersGone => write!(f, "batcher workers gone"),
            SubmitError::Shed { estimated_wait_s } => write!(
                f,
                "shed at admission: estimated queue wait {:.1}ms exceeds deadline",
                estimated_wait_s * 1e3
            ),
            SubmitError::Invalid { id, elems, expected } => write!(
                f,
                "request {id} has {elems} elements, expected {expected} (S·M)"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

impl Planner {
    pub fn new(cfg: PlannerConfig) -> Self {
        Self {
            cfg: PlannerConfig {
                max_batch: cfg.max_batch.max(1),
                linger: cfg.linger,
                queue_depth: cfg.queue_depth.max(1),
            },
            submits: VecDeque::new(),
            decodes: VecDeque::new(),
            retries: VecDeque::new(),
            window: Vec::new(),
            deadline: None,
            closed: false,
        }
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Fresh submissions still waiting in the bounded queue.
    pub fn queued(&self) -> usize {
        self.submits.len()
    }

    /// Whether the bounded queue can admit another fresh submission.
    pub fn has_space(&self) -> bool {
        self.submits.len() < self.cfg.queue_depth
    }

    /// Offer a fresh submission to the bounded queue.
    pub fn offer_submit(&mut self, q: QueuedRequest) -> SubmitOutcome {
        if self.closed {
            return SubmitOutcome::Closed;
        }
        if !self.has_space() {
            return SubmitOutcome::Full;
        }
        self.submits.push_back(q);
        SubmitOutcome::Accepted
    }

    /// Push a decode re-entry (prefill-done unlocked this step). Never
    /// bounded, accepted during shutdown too — the drain must finish
    /// every admitted request's decode loop.
    pub fn push_decode(&mut self, q: QueuedRequest) {
        self.decodes.push_back(q);
    }

    /// Push a retry of a request whose replica failed mid-serve. Front
    /// of the priority order, accepted during shutdown (the drain owes
    /// it a response like any admitted request).
    pub fn push_retry(&mut self, q: QueuedRequest) {
        self.retries.push_back(q);
    }

    /// Begin shutdown: no new submissions, everything already admitted
    /// still drains.
    pub fn close(&mut self) {
        self.closed = true;
    }

    /// Move queued requests into the open window — retries first, then
    /// the decode lane (the continuous-batching priority), fresh
    /// submissions last, strictly FIFO within each lane. Opens the
    /// linger window when the first request lands. Returns how many
    /// bounded-queue slots were freed.
    fn ingest(&mut self, now: Instant) -> usize {
        let mut freed = 0;
        while self.window.len() < self.cfg.max_batch {
            let q = match self.retries.pop_front().or_else(|| self.decodes.pop_front()) {
                Some(q) => q,
                None => match self.submits.pop_front() {
                    Some(q) => {
                        freed += 1;
                        q
                    }
                    None => break,
                },
            };
            if self.window.is_empty() {
                self.deadline = Some(now + self.cfg.linger);
            }
            self.window.push(q);
        }
        freed
    }

    /// Seal the open window into an executable batch.
    fn seal(&mut self) -> Vec<QueuedRequest> {
        self.deadline = None;
        std::mem::take(&mut self.window)
    }

    /// Advance the state machine. `now` is the caller's clock (tests
    /// fabricate it); `open` is a snapshot of the requests still owed a
    /// final response anywhere in the system (queues, window, or in
    /// flight inside an executor). A stale-high `open` only delays the
    /// shutdown fast-seal until the linger deadline — never loses work.
    pub fn poll(&mut self, now: Instant, open: usize) -> Poll {
        let freed = self.ingest(now);
        if !self.window.is_empty() {
            let full = self.window.len() >= self.cfg.max_batch;
            let expired = self.deadline.map_or(true, |d| now >= d);
            // Shutdown fast path: every open request is already in the
            // window, so no arrival can ever fill it further —
            // lingering would wait for nobody.
            let drained = self.closed && open == self.window.len();
            let step = if full || expired || drained {
                Step::Execute(self.seal())
            } else {
                Step::ParkUntil(self.deadline.expect("open window has a deadline"))
            };
            return Poll { step, freed };
        }
        // Empty window ⇒ all three lanes are empty (ingest drained them).
        let step = if self.closed && open == 0 {
            Step::Exit
        } else {
            // Either still serving, or closed with requests in flight
            // whose decode re-entries / completions will wake us.
            Step::Park
        };
        Poll { step, freed }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> QueuedRequest {
        QueuedRequest::fresh(EmbeddedRequest::synthetic(id, 2, 2))
    }

    fn planner(max_batch: usize, linger_us: u64, depth: usize) -> Planner {
        Planner::new(PlannerConfig {
            max_batch,
            linger: Duration::from_micros(linger_us),
            queue_depth: depth,
        })
    }

    fn ids(batch: &[QueuedRequest]) -> Vec<u64> {
        batch.iter().map(|q| q.req.id).collect()
    }

    #[test]
    fn empty_planner_parks() {
        let mut p = planner(4, 100, 8);
        assert!(matches!(p.poll(Instant::now(), 0).step, Step::Park));
    }

    #[test]
    fn window_full_executes_immediately_in_fifo_order() {
        let mut p = planner(3, 1_000_000, 8);
        for i in 0..5 {
            assert_eq!(p.offer_submit(req(i)), SubmitOutcome::Accepted);
        }
        let now = Instant::now();
        // First poll: window fills to max_batch straight from the
        // queue — no lingering, strict submission order.
        match p.poll(now, 5).step {
            Step::Execute(b) => assert_eq!(ids(&b), vec![0, 1, 2]),
            s => panic!("expected Execute, got {s:?}"),
        }
        // Remainder lingers (2 < max_batch) until the deadline.
        match p.poll(now, 2).step {
            Step::ParkUntil(d) => assert!(d > now),
            s => panic!("expected ParkUntil, got {s:?}"),
        }
        match p.poll(now + Duration::from_secs(2), 2).step {
            Step::Execute(b) => assert_eq!(ids(&b), vec![3, 4]),
            s => panic!("expected Execute at expiry, got {s:?}"),
        }
    }

    #[test]
    fn linger_window_fills_across_polls() {
        let mut p = planner(4, 500, 8);
        let t0 = Instant::now();
        p.offer_submit(req(0));
        let deadline = match p.poll(t0, 1).step {
            Step::ParkUntil(d) => d,
            s => panic!("expected ParkUntil, got {s:?}"),
        };
        // More arrivals within the window join the same batch; the
        // deadline does not reset.
        p.offer_submit(req(1));
        p.offer_submit(req(2));
        match p.poll(t0 + Duration::from_micros(100), 3).step {
            Step::ParkUntil(d) => assert_eq!(d, deadline, "linger deadline must not reset"),
            s => panic!("expected ParkUntil, got {s:?}"),
        }
        p.offer_submit(req(3));
        match p.poll(t0 + Duration::from_micros(200), 4).step {
            Step::Execute(b) => assert_eq!(ids(&b), vec![0, 1, 2, 3]),
            s => panic!("window reached max_batch, expected Execute, got {s:?}"),
        }
    }

    #[test]
    fn decode_reentries_outrank_fresh_submissions() {
        let mut p = planner(4, 1_000_000, 8);
        p.offer_submit(req(10));
        p.offer_submit(req(11));
        p.push_decode(req(1));
        p.push_decode(req(2));
        match p.poll(Instant::now(), 4).step {
            Step::Execute(b) => assert_eq!(ids(&b), vec![1, 2, 10, 11]),
            s => panic!("expected Execute, got {s:?}"),
        }
    }

    #[test]
    fn retries_outrank_decodes_and_fresh_submissions() {
        let mut p = planner(4, 1_000_000, 8);
        p.offer_submit(req(10));
        p.push_decode(req(5));
        p.push_retry(QueuedRequest::retry(
            EmbeddedRequest::synthetic(1, 2, 2),
            Instant::now(),
            1,
        ));
        p.push_retry(QueuedRequest::retry(
            EmbeddedRequest::synthetic(2, 2, 2),
            Instant::now(),
            2,
        ));
        match p.poll(Instant::now(), 4).step {
            Step::Execute(b) => {
                assert_eq!(ids(&b), vec![1, 2, 5, 10]);
                assert_eq!(b[0].attempts, 1);
                assert_eq!(b[1].attempts, 2);
                assert_eq!(b[2].attempts, 0);
            }
            s => panic!("expected Execute, got {s:?}"),
        }
    }

    #[test]
    fn submit_error_displays_each_variant() {
        assert_eq!(SubmitError::Closed.to_string(), "batcher closed");
        assert_eq!(SubmitError::QueueFull.to_string(), "submit queue full");
        assert_eq!(SubmitError::WorkersGone.to_string(), "batcher workers gone");
        let s = SubmitError::Shed { estimated_wait_s: 0.25 }.to_string();
        assert!(s.contains("250.0ms"), "{s}");
    }

    #[test]
    fn bounded_queue_backpressures_fresh_but_not_decode() {
        let mut p = planner(8, 100, 2);
        assert_eq!(p.offer_submit(req(0)), SubmitOutcome::Accepted);
        assert_eq!(p.offer_submit(req(1)), SubmitOutcome::Accepted);
        assert_eq!(p.offer_submit(req(2)), SubmitOutcome::Full);
        // The decode lane is never bounded.
        for i in 0..32 {
            p.push_decode(req(100 + i));
        }
        // Drain everything: 32 decodes seal as four full windows (no
        // submit slots freed), then the two fresh submissions form a
        // partial window that lingers and seals at its deadline. Every
        // bounded slot is reported freed exactly once.
        let mut now = Instant::now();
        let mut freed = 0;
        let mut executed = 0;
        let mut polls = 0;
        loop {
            polls += 1;
            assert!(polls < 64, "drain did not converge");
            let poll = p.poll(now, 34);
            freed += poll.freed;
            match poll.step {
                Step::Execute(b) => executed += b.len(),
                Step::ParkUntil(d) => now = d, // jump past the linger
                Step::Park => break,
                Step::Exit => panic!("not closed, must not exit"),
            }
        }
        assert_eq!(executed, 34);
        assert_eq!(freed, 2, "every bounded slot must be reported freed exactly once");
        assert!(p.has_space());
    }

    #[test]
    fn close_rejects_new_submits_but_drains_admitted_work() {
        let mut p = planner(4, 1_000_000, 8);
        p.offer_submit(req(0));
        p.close();
        assert_eq!(p.offer_submit(req(1)), SubmitOutcome::Closed);
        // Decode re-entries are still admitted during the drain.
        p.push_decode(req(2));
        let now = Instant::now();
        // open == window.len() after ingest (2 requests, both in the
        // window): no arrival can fill the window further — seal now
        // instead of waiting out the linger.
        match p.poll(now, 2).step {
            Step::Execute(b) => assert_eq!(ids(&b), vec![2, 0]),
            s => panic!("expected shutdown fast-seal, got {s:?}"),
        }
        // Drained and closed: exit.
        assert!(matches!(p.poll(now, 0).step, Step::Exit));
    }

    #[test]
    fn closed_with_inflight_work_parks_instead_of_exiting() {
        let mut p = planner(4, 100, 8);
        p.close();
        // 3 requests are inside an executor (open > 0): their decode
        // re-entries may still arrive, so the planner parks rather than
        // exits — the executor's completion events re-poll it.
        assert!(matches!(p.poll(Instant::now(), 3).step, Step::Park));
        assert!(matches!(p.poll(Instant::now(), 0).step, Step::Exit));
    }

    #[test]
    fn closed_window_with_inflight_peers_lingers_until_deadline() {
        let mut p = planner(4, 500, 8);
        p.close();
        p.push_decode(req(0));
        let t0 = Instant::now();
        // open = 3: two other requests are mid-execution elsewhere, so
        // their re-entries could still join this window — linger.
        match p.poll(t0, 3).step {
            Step::ParkUntil(d) => assert!(d > t0),
            s => panic!("expected ParkUntil, got {s:?}"),
        }
        match p.poll(t0 + Duration::from_millis(10), 3).step {
            Step::Execute(b) => assert_eq!(ids(&b), vec![0]),
            s => panic!("expected Execute at expiry, got {s:?}"),
        }
    }
}
