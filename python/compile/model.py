"""L2: the JAX MoE model, assembled from the L1 Pallas kernels.

The model is factored into the four *stage functions* the DEP
coordinator schedules independently — attention (AG), gate (AG), shared
expert (AG), expert FFN (EG) — because each stage becomes its own AOT
HLO artifact executed on a different logical device group. A fused
per-layer reference path exists for validation only.

Python in this package runs exclusively at build time (``make
artifacts``); the Rust coordinator never imports it.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import attention as attn_k
from compile.kernels import expert_ffn as ffn_k
from compile.kernels import gating as gate_k
from compile.kernels import ref
from compile import configs


# ---------------------------------------------------------------------------
# Stage functions (each one becomes an AOT artifact).
# ---------------------------------------------------------------------------

def attention_stage(h, wq, wk, wv, wo, *, n_heads, d_k, d_v, causal=True):
    """AG stage: QKV projections + Pallas attention + output projection,
    with residual. h: [B, S, M] -> [B, S, M]."""
    b, s, _m = h.shape
    q = (h @ wq.T).reshape(b, s, n_heads, d_k).transpose(0, 2, 1, 3)
    k = (h @ wk.T).reshape(b, s, n_heads, d_k).transpose(0, 2, 1, 3)
    v = (h @ wv.T).reshape(b, s, n_heads, d_v).transpose(0, 2, 1, 3)
    block = min(16, s)
    o = attn_k.attention(q, k, v, causal=causal, block_q=block, block_k=block)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_v)
    return h + o @ wo.T


def gate_stage(x, w_gate, *, top_k):
    """AG stage: routing. x: [N, M] -> (probs [N,k], idx [N,k] i32)."""
    return gate_k.gate_topk(x, w_gate, top_k)


def ffn_stage(x, w_gate, w_up, w_down):
    """Shared-expert or routed-expert FFN (identical compute shape,
    §3.1): x: [N, M] -> [N, M], via the Pallas SwiGLU kernel."""
    return ffn_k.expert_ffn(x, w_gate, w_up, w_down)


# ---------------------------------------------------------------------------
# Weights.
# ---------------------------------------------------------------------------

def init_layer_weights(cfg: configs.ModelConfig, rng: np.random.Generator):
    """Deterministic small-scale weights for one layer (f32 numpy).

    Scale 1/sqrt(fan_in) keeps activations O(1) over the residual stream
    without normalization layers (documented simplification)."""
    m, h = cfg.embed, cfg.ffn_hidden
    nh, dk, dv = cfg.n_heads, cfg.d_k, cfg.d_v

    def w(shape):
        fan_in = shape[-1]
        return (rng.standard_normal(shape) * (0.4 / np.sqrt(fan_in))).astype(np.float32)

    lw = {
        "n_heads": nh, "d_k": dk, "d_v": dv,
        "wq": w((nh * dk, m)),
        "wk": w((nh * dk, m)),
        "wv": w((nh * dv, m)),
        "wo": w((m, nh * dv)),
        "gate_w": w((cfg.n_experts, m)),
        "exp_gate": w((cfg.n_experts, h, m)),
        "exp_up": w((cfg.n_experts, h, m)),
        "exp_down": w((cfg.n_experts, m, h)),
    }
    if cfg.n_shared > 0:
        # One shared expert in the tiny config.
        lw["shared_gate"] = w((h, m))
        lw["shared_up"] = w((h, m))
        lw["shared_down"] = w((m, h))
    return lw


def init_weights(cfg: configs.ModelConfig, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [init_layer_weights(cfg, rng) for _ in range(cfg.n_layers)]


# ---------------------------------------------------------------------------
# Full forward through the kernel path (validation only — the serving
# path replays exactly these stages from Rust).
# ---------------------------------------------------------------------------

def moe_layer(h, lw, top_k, causal=True):
    """One layer through the *kernel* stages, with the same routing and
    combine semantics the Rust coordinator implements."""
    h = attention_stage(
        h, lw["wq"], lw["wk"], lw["wv"], lw["wo"],
        n_heads=lw["n_heads"], d_k=lw["d_k"], d_v=lw["d_v"], causal=causal,
    )
    b, s, m = h.shape
    x = h.reshape(b * s, m)
    probs, idx = gate_stage(x, lw["gate_w"], top_k=top_k)

    n_experts = lw["gate_w"].shape[0]
    routed = jnp.zeros_like(x)
    for e in range(n_experts):
        # Token selection mirrors the coordinator's router: each expert
        # processes the tokens routed to it; the combine applies gate
        # weights. Dense masking keeps the validation path simple.
        out_e = ffn_stage(x, lw["exp_gate"][e], lw["exp_up"][e], lw["exp_down"][e])
        weight_e = jnp.sum(jnp.where(idx == e, probs, 0.0), axis=-1, keepdims=True)
        routed = routed + weight_e * out_e

    out = x + routed
    if "shared_gate" in lw:
        out = out + ffn_stage(x, lw["shared_gate"], lw["shared_up"], lw["shared_down"])
    return out.reshape(b, s, m)


def model_forward(h, weights, top_k, causal=True):
    for lw in weights:
        h = moe_layer(h, lw, top_k, causal=causal)
    return h


def reference_forward(h, weights, top_k, causal=True):
    """The pure-jnp oracle (no Pallas), for cross-checking."""
    return ref.ref_model(h, weights, top_k, causal=causal)
