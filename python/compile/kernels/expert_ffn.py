"""L1 Pallas kernel: SwiGLU expert feed-forward network.

The paper's EG hot spot (Eq. 3): for each expert,
``y = W_D · (Swish(W_gate·x) ⊗ (W_U·x))``. On the paper's GPUs this is a
grouped-GEMM CUDA kernel; the TPU re-think (DESIGN.md
§Hardware-Adaptation) is:

* **VMEM tiling instead of shared-memory threadblocks** — BlockSpec
  carves the token dimension into ``block_n`` rows; each grid step holds
  one token tile plus the full (H, M) weight panels in VMEM. For the
  paper-scale shapes (M≈4-5k, H≈1.5k, bf16) a (128 tokens × weights)
  working set is ≈ (128·M + 2·H·M + M·H + 128·H)·2B ≈ 13 MB < 16 MB VMEM
  with fp32 accumulators in scratch, so one-level tiling suffices; wider
  models would additionally tile H (the kernel exposes ``block_h``).
* **MXU-shaped GEMMs instead of WMMA fragments** — both GEMMs are
  expressed as plain ``jnp.dot`` on (128, M)×(M, H) panels, which Mosaic
  maps onto 128×128 MXU passes; Swish and the Hadamard product stay in
  the VPU between the two MXU passes, avoiding an HBM round-trip for the
  (N, H) intermediate — that round-trip is exactly what the fused CUDA
  kernel avoided with shared memory.
* Grid order is token-major so consecutive grid steps reuse the resident
  weight panels (double-buffering friendly).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical
numerics (validated against ``ref.ref_ffn`` in pytest).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One token-tile of the fused SwiGLU FFN.

    x:  [block_n, M]   (VMEM tile)
    wg, wu: [H, M]; wd: [M, H] (resident panels)
    o:  [block_n, M]
    """
    x = x_ref[...]
    # MXU pass 1: gate and up projections (accumulate in f32).
    z_gate = jnp.dot(x, wg_ref[...].T, preferred_element_type=jnp.float32)
    z_up = jnp.dot(x, wu_ref[...].T, preferred_element_type=jnp.float32)
    # VPU: Swish(z_gate) ⊗ z_up, no HBM round-trip.
    hidden = (z_gate * jax.nn.sigmoid(z_gate)) * z_up
    # MXU pass 2: down projection.
    o_ref[...] = jnp.dot(
        hidden.astype(x.dtype), wd_ref[...].T, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n",))
def expert_ffn(x, w_gate, w_up, w_down, block_n=128):
    """Fused SwiGLU FFN via Pallas.

    x: [N, M]; w_gate, w_up: [H, M]; w_down: [M, H]  ->  [N, M]

    ``block_n`` is the token-tile size; N is padded up to a multiple
    internally (zero rows compute zeros and are sliced off).
    """
    n, m = x.shape
    h = w_gate.shape[0]
    assert w_gate.shape == (h, m) and w_up.shape == (h, m), "weight shape"
    assert w_down.shape == (m, h), "down-projection shape"

    bn = min(block_n, n) if n > 0 else 1
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    n_padded = x.shape[0]

    out = pl.pallas_call(
        _ffn_kernel,
        grid=(n_padded // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((h, m), lambda i: (0, 0)),
            pl.BlockSpec((h, m), lambda i: (0, 0)),
            pl.BlockSpec((m, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_padded, m), x.dtype),
        interpret=True,
    )(x, w_gate, w_up, w_down)
    return out[:n]


def vmem_bytes(block_n, m, h, elem_bytes=2, acc_bytes=4):
    """Estimated VMEM working set of one grid step (perf-model input for
    DESIGN.md §Perf; see EXPERIMENTS.md §Perf L1 for the block sweep)."""
    tokens = block_n * m * elem_bytes          # x tile
    weights = (2 * h * m + m * h) * elem_bytes  # wg, wu, wd panels
    acc = 2 * block_n * h * acc_bytes           # z_gate, z_up accumulators
    out = block_n * m * acc_bytes               # output accumulator
    return tokens + weights + acc + out


def mxu_utilization_estimate(block_n, m, h):
    """Fraction of MXU 128×128 pass slots doing useful work for one grid
    step (structure metric — interpret-mode wallclock is NOT a TPU
    proxy)."""
    def eff(dim):
        full = dim // 128
        rem = dim % 128
        passes = full + (1 if rem else 0)
        return dim / (passes * 128) if passes else 1.0

    return eff(block_n) * eff(m) * eff(h)
