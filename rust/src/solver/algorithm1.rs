//! Algorithm 1: FinDEP configuration search (§4.3).
//!
//! ```text
//! for m_a = MA_max downto 1:
//!     r1 = getMaxR1(...)            # memory-constrained
//!     if r1 == 0 or r1 == prev r1: continue   # Pareto-dominated
//!     for order in {ASAS, AASS}:
//!         r2*, tps = argmin_{r2} makespan(...)  # convex in 1/r2 (Thm 4)
//!         m_e = m_a·ag·top_k·S / (r2*·E)
//!         keep the best
//! ```
//!
//! ## Candidate evaluation (the hot path)
//!
//! All candidate probes run through a reusable [`Evaluator`]: the stage
//! models are derived once per solve, the task DAG is rebuilt into a
//! [`PlanBuffers`] arena, and the discrete-event engine executes into a
//! [`SimBuffers`] arena — zero allocations per `(m_a, order, r2)` probe
//! once the arenas are warm. ASAS probes additionally shortcut through
//! the §4.2 closed forms ([`Analytic::from_config`]), which coincide
//! with the engine exactly on those plans (pinned by
//! `rust/tests/simulator_vs_analytic.rs`); AASS and fused candidates go
//! through the engine, which evaluates them exactly instead of by
//! approximation. The inner r2 ternary search memoizes its probes (the
//! search revisits midpoints), engine-probed winners skip the final
//! re-simulation (the probe already was exact), and repeat plan shapes
//! ride the engine's cached-topology duration-only fast path. The final
//! winner of an analytic probe run is still re-evaluated on the engine.
//! [`EvalMode::AllocPerCandidate`] preserves the original
//! allocate-per-probe behaviour so `benches/solver_speed.rs` can
//! measure both paths against each other. [`solve_with`] lets an outer
//! search (solver::splitsearch) share one evaluator — and with it the
//! arenas and topology cache — across many instances.
//!
//! Cyclic or degenerate candidates (a corrupted `PlanConfig` from an
//! outer searcher) degrade into skipped candidates: the engine reports
//! a [`crate::simulator::SimError`] instead of panicking, and the
//! throughput guard keeps `inf`/NaN out of the argmax.
//!
//! ## Warm, pruned, anytime re-solves
//!
//! Serving-loop re-solves are rarely cold: the missed shape is usually
//! one KV bucket or one batch step away from a plan already in the
//! [`crate::solver::PlanCache`]. Three mechanisms make re-solves cheap
//! without changing the answer:
//!
//! * **Warm seeding** ([`WarmStart`], [`solve_warm`] /
//!   [`solve_online_with`]): the seed config orders the `(m_a, r1)`
//!   sweep outward from its row, its `r2` pivots the inner search
//!   (certified against its strictly-worse neighbors under the same
//!   Theorem-4 unimodality the ternary search rests on), and the seed
//!   is *re-evaluated on the target instance* before its throughput is
//!   installed as the incumbent — a neighbor shape's numbers are never
//!   trusted, so pruning stays admissible and the result is
//!   bit-identical to the cold sweep.
//! * **Bound-based pruning** ([`SolverParams::prune`]): the §4.2
//!   admissible analytic bound ([`row_bound`], shared with
//!   `solver::splitsearch`) skips whole rows that cannot beat the
//!   incumbent, and candidates whose closed-form probe sits further
//!   below an engine-achieved incumbent than the pinned
//!   analytic/engine agreement skip their final engine evaluation.
//!   The winner is bit-identical with pruning on or off (candidates
//!   are reduced in canonical order regardless of visit order, and
//!   only provably-losing work is skipped); the prune-off oracle test
//!   pins this.
//! * **Anytime budget** ([`SolverParams::budget`]): when the hard
//!   latency budget expires the current incumbent is returned flagged
//!   [`Solution::exhaustive`]` = false`; callers finish the sweep off
//!   the hot path (`PlanCache::publish_refined`).

use std::time::{Duration, Instant};

use crate::config::{Cluster, ExpertLoad, ExpertPlacement, GroupSplit, ModelConfig, Phase, Testbed};
use crate::perfmodel::StageModels;
use crate::sched::analytic::Analytic;
use crate::sched::{Order, Plan, PlanBuffers, PlanConfig};
use crate::simulator::engine::{simulate_into, SimBuffers};
use crate::solver::memory::MemoryModel;
use crate::util::stats::ternary_min_int;

/// A solver problem instance.
///
/// `seq_len` is the tokens each sample contributes to one forward pass:
/// the prompt length for prefill instances, 1 for decode instances
/// (whose KV length lives in `phase`) — so `throughput_tokens` counts
/// prompt tokens/s for prefill and generated tokens/s for decode.
#[derive(Debug, Clone)]
pub struct Instance {
    pub model: ModelConfig,
    /// Hardware the instance runs on. Testbed-built instances hold a
    /// [`Cluster::single_pool`], whose derived models are bit-identical
    /// to the retired direct-Testbed path.
    pub cluster: Cluster,
    pub split: GroupSplit,
    pub seq_len: usize,
    pub phase: Phase,
    /// Expert → shard assignment (with replication) the instance's
    /// stage and memory models are priced under. Defaults to the
    /// idealized uniform placement, which reproduces the legacy
    /// derivation bit for bit (`tests/placement_equivalence.rs`).
    pub placement: ExpertPlacement,
    /// Per-expert relative token load the placement is priced against.
    /// Defaults to uniform (all-ones).
    pub load: ExpertLoad,
}

impl Instance {
    pub fn new(model: ModelConfig, testbed: Testbed, split: GroupSplit, seq_len: usize) -> Self {
        Self::on_cluster(model, Cluster::single_pool(&testbed), split, seq_len)
    }

    /// An instance on a (possibly heterogeneous) cluster: the split's
    /// `ag` draws from the attention pool, `eg` from the expert pool.
    pub fn on_cluster(
        model: ModelConfig,
        cluster: Cluster,
        split: GroupSplit,
        seq_len: usize,
    ) -> Self {
        // The solve boundary: an empty batch shape (S = 0, e.g. from an
        // empty serving window) must fail loudly here, not surface as a
        // degenerate all-zero-duration plan winning the argmax.
        assert!(seq_len >= 1, "zero-length sequence reached the solver");
        let placement = ExpertPlacement::uniform(model.n_experts, split.eg);
        let load = ExpertLoad::uniform(model.n_experts);
        Self { model, cluster, split, seq_len, phase: Phase::Prefill, placement, load }
    }

    /// Price this instance under a concrete expert placement and load
    /// instead of the uniform default. Stage models, memory accounting,
    /// and every solve on the instance pick the pair up automatically.
    pub fn with_placement(mut self, placement: ExpertPlacement, load: ExpertLoad) -> Self {
        assert_eq!(placement.n_experts(), self.model.n_experts, "placement/model mismatch");
        assert_eq!(placement.n_shards(), self.split.eg, "placement shards must match split.eg");
        assert_eq!(load.n_experts(), self.model.n_experts, "load/model mismatch");
        self.placement = placement;
        self.load = load;
        self
    }

    /// A decode-phase instance: every sample generates one token per
    /// forward pass against `kv_len` cached KV entries.
    pub fn decode(model: ModelConfig, testbed: Testbed, split: GroupSplit, kv_len: usize) -> Self {
        Self::decode_on_cluster(model, Cluster::single_pool(&testbed), split, kv_len)
    }

    /// Decode-phase instance on a cluster (see [`Self::on_cluster`]).
    pub fn decode_on_cluster(
        model: ModelConfig,
        cluster: Cluster,
        split: GroupSplit,
        kv_len: usize,
    ) -> Self {
        let mut inst = Self::on_cluster(model, cluster, split, 1);
        inst.phase = Phase::Decode { kv_len };
        inst
    }

    pub fn stage_models(&self) -> StageModels {
        StageModels::for_cluster_placed(
            &self.model,
            &self.cluster,
            self.split,
            self.seq_len,
            self.phase,
            &self.placement,
            &self.load,
        )
    }

    pub fn memory(&self) -> MemoryModel {
        MemoryModel::for_cluster(&self.model, &self.cluster, self.split, self.seq_len, self.phase)
            .with_placement(self.placement.clone())
    }

    /// Build the reusable candidate evaluator for this instance.
    pub fn evaluator(&self) -> Evaluator {
        Evaluator::new(self)
    }

    /// Evaluate one concrete configuration end-to-end (build plan +
    /// simulate), returning (makespan seconds, tokens/s). One-shot
    /// convenience path: allocates fresh stage models and arenas per
    /// call — searchers should hold an [`Evaluator`] instead.
    pub fn evaluate(&self, cfg: PlanConfig) -> (f64, f64) {
        self.evaluator().evaluate(cfg)
    }
}

/// Reusable candidate evaluator: stage models derived once, plan and
/// simulation arenas rewritten in place per candidate.
#[derive(Debug, Clone)]
pub struct Evaluator {
    sm: StageModels,
    n_layers: usize,
    ag: usize,
    seq_len: usize,
    plan_buf: PlanBuffers,
    sim_buf: SimBuffers,
    /// Scratch for `best_r2`'s per-call probe memo (capacity persists
    /// across calls so the memo costs no steady-state allocation).
    r2_memo: Vec<f64>,
}

impl Evaluator {
    pub fn new(inst: &Instance) -> Evaluator {
        Evaluator {
            sm: inst.stage_models(),
            n_layers: inst.model.n_layers,
            ag: inst.split.ag,
            seq_len: inst.seq_len,
            plan_buf: PlanBuffers::new(),
            sim_buf: SimBuffers::new(),
            r2_memo: Vec::new(),
        }
    }

    /// Re-target the evaluator at another instance while keeping the
    /// plan/simulation arenas (and the engine's per-shape topology
    /// cache) warm — the split search re-solves many instances whose
    /// candidate plans share topologies and differ only in durations.
    pub fn reset(&mut self, inst: &Instance) {
        self.sm = inst.stage_models();
        self.n_layers = inst.model.n_layers;
        self.ag = inst.split.ag;
        self.seq_len = inst.seq_len;
    }

    /// The instance's stage models (shared with every probe).
    pub fn stage_models(&self) -> &StageModels {
        &self.sm
    }

    /// Would [`Evaluator::probe_makespan`] answer `cfg` from the §4.2
    /// closed forms (true) or from the discrete-event engine (false)?
    pub fn probe_is_analytic(&self, cfg: &PlanConfig) -> bool {
        Analytic::from_config(&self.sm, cfg).is_some()
    }

    /// Duration-only simulations served from the engine's topology
    /// cache so far (diagnostic; see `SimBuffers::topo_hits`).
    pub fn topo_hits(&self) -> u64 {
        self.sim_buf.topo_hits()
    }

    /// Tokens/s for a candidate whose exact engine makespan is already
    /// known — bit-identical to `SimResult::throughput_tokens` on the
    /// plan the engine would rebuild (same `PlanConfig::total_tokens`
    /// numerator, same degenerate-makespan guard), without
    /// re-simulating it.
    fn throughput_for(&self, cfg: &PlanConfig, makespan: f64) -> f64 {
        if !makespan.is_finite() || makespan <= 0.0 {
            return 0.0;
        }
        cfg.total_tokens(self.ag, self.seq_len) / makespan
    }

    /// Exact evaluation on the discrete-event engine, allocation-free
    /// once the arenas are warm. Returns (makespan, tokens/s); a
    /// degenerate/cyclic candidate reports `(inf, 0.0)` and thus can
    /// never win an argmax.
    pub fn evaluate(&mut self, cfg: PlanConfig) -> (f64, f64) {
        let plan = Plan::build_into(
            &mut self.plan_buf,
            &self.sm,
            cfg,
            self.n_layers,
            self.ag,
            self.seq_len,
        );
        match simulate_into(plan, &mut self.sim_buf) {
            Ok(sim) => (sim.makespan, sim.throughput_tokens(plan)),
            Err(_) => (f64::INFINITY, 0.0),
        }
    }

    /// Makespan-only probe for the inner r2 search: ASAS non-fused
    /// candidates go through the §4.2 closed forms (no DAG at all),
    /// everything else through the engine arenas.
    pub fn probe_makespan(&mut self, cfg: PlanConfig) -> f64 {
        if let Some(a) = Analytic::from_config(&self.sm, &cfg) {
            return a.makespan(self.n_layers);
        }
        self.evaluate(cfg).0
    }
}

/// How candidate probes are evaluated — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvalMode {
    /// Original bring-up behaviour: fresh stage models + fresh task DAG
    /// + fresh simulation vectors per candidate. Kept as the measured
    /// baseline for `benches/solver_speed.rs`.
    AllocPerCandidate,
    /// Arena-reuse + closed-form ASAS probes (the default).
    Buffered,
}

/// Search-space caps. `ma_cap` mirrors the paper's small per-GPU
/// micro-batch regime (Tables 3/4 sweep 1..4); `r1_cap`/`r2_cap` bound
/// the pipeline degrees (launch overhead makes extreme degrees useless,
/// §2.3).
#[derive(Debug, Clone, Copy)]
pub struct SolverParams {
    pub ma_cap: usize,
    pub r1_cap: usize,
    pub r2_cap: usize,
    /// §4.2 bound-based row pruning + analytic screening of final
    /// engine evaluations. The winner is bit-identical with pruning on
    /// or off (see the module docs); `false` preserves the original
    /// cold sweep exactly and serves as the oracle in tests.
    pub prune: bool,
    /// Hard latency budget for the sweep (anytime mode): when it
    /// expires, the best candidate found so far is returned flagged
    /// [`Solution::exhaustive`]` = false`. `None` (the default) never
    /// truncates; neither does a budget the sweep finishes inside.
    pub budget: Option<Duration>,
    /// SLO-driven goodput mode: when set, a candidate only counts if
    /// its batch makespan is ≤ this many seconds — the per-batch proxy
    /// for a TTFT target (prefill instances) or a TPOT target (decode
    /// instances). The solve becomes "maximize tokens/s subject to the
    /// latency cap", and additionally sweeps the *sub-maximal* `(m_a,
    /// r1)` rows that pure throughput search Pareto-skips, since a
    /// smaller in-flight batch may be the only way under the cap.
    /// `None` (the default) is the pure-throughput objective,
    /// bit-identical to the pre-SLO solver. Pruning stays admissible
    /// with a cap: the incumbent only ever holds cap-feasible
    /// throughput, and the §4.2 row bound dominates every candidate in
    /// the row whether or not it meets the cap.
    pub max_makespan: Option<f64>,
}

impl Default for SolverParams {
    fn default() -> Self {
        // The paper's experimental regime sweeps m_a and r1 over 1..4
        // (Tables 3/4); activation working sets and latency SLOs bound
        // in-flight samples well before raw KV memory does.
        Self { ma_cap: 4, r1_cap: 4, r2_cap: 64, prune: true, budget: None, max_makespan: None }
    }
}

/// Solver output.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub config: PlanConfig,
    pub makespan: f64,
    pub throughput_tokens: f64,
    /// Wall time the solver itself took (the paper's <1 s claim).
    pub solve_seconds: f64,
    /// Number of (m_a, r1, order, r2) evaluations performed.
    pub evals: usize,
    /// (m_a, r1) rows skipped whole by the §4.2 admissible bound.
    pub pruned_rows: usize,
    /// True when a [`WarmStart`] seed config steered this solve.
    pub warm_seeded: bool,
    /// False when the latency budget expired before the sweep covered
    /// every row — the plan is the best incumbent so far, and a
    /// refinement pass (`PlanCache::publish_refined`) can finish the
    /// sweep off the hot path.
    pub exhaustive: bool,
}

impl Solution {
    fn candidate(config: PlanConfig, makespan: f64, throughput_tokens: f64) -> Self {
        Self {
            config,
            makespan,
            throughput_tokens,
            solve_seconds: 0.0,
            evals: 0,
            pruned_rows: 0,
            warm_seeded: false,
            exhaustive: true,
        }
    }
}

/// Seed for a warm re-solve.
///
/// Soft seeds (`hard = false`, from [`WarmStart::from_solution`], e.g.
/// a `PlanCache::nearest` neighbor) steer the sweep — visit order, r2
/// pivot — and are **re-evaluated on the target instance** before
/// their throughput is installed as the incumbent, so the result stays
/// bit-identical to a cold solve even when the seed came from a
/// different shape. Hard incumbents (`hard = true`, from
/// [`WarmStart::incumbent`]) are caller-vouched pruning floors — the
/// split search passes its best total so far — and may legitimately
/// turn the solve into `None` when no candidate can beat them.
#[derive(Debug, Clone, Copy)]
pub struct WarmStart {
    /// Seed configuration; `None` for a bare hard incumbent.
    pub config: Option<PlanConfig>,
    /// Seed tokens/s. Advisory for soft seeds (re-evaluated before
    /// use); the pruning floor for hard incumbents.
    pub throughput_tokens: f64,
    pub hard: bool,
}

impl WarmStart {
    /// Seed a re-solve from a previously solved plan.
    pub fn from_solution(s: &Solution) -> Self {
        Self { config: Some(s.config), throughput_tokens: s.throughput_tokens, hard: false }
    }

    /// A bare pruning floor: skip all work that provably cannot beat
    /// `throughput_tokens` (the caller holds that solution elsewhere).
    pub fn incumbent(throughput_tokens: f64) -> Self {
        Self { config: None, throughput_tokens, hard: true }
    }
}

/// Relative slack when screening probe values against an
/// engine-achieved incumbent: the §4.2 closed forms agree with the
/// engine to ~1e-9 relative (pinned by `simulator_vs_analytic` and
/// `evaluator_matches_one_shot_instance_evaluate`), so a candidate
/// whose analytic throughput sits more than this fraction below an
/// achieved value cannot win the exact comparison. 100× the pinned
/// agreement for float headroom; paper-instance candidate gaps are
/// ≥ 1e-5 relative, so no screening opportunity is lost.
const SCREEN_EPS: f64 = 1e-7;

/// Steps a warm r2 pivot may walk downhill before falling back to the
/// full ternary sweep (whose revisits of walked points are free via
/// the probe memo).
const PIVOT_WALK_CAP: usize = 8;

/// Admissible per-row throughput upper bound (§4.2): the engine's
/// makespan over `T` layers is at least `T·r1·F(m_a, r2)` (each
/// resource executes its tasks non-preemptively), `F` at fixed `m_a`
/// is minimized at `r2 = 1` (per-part launch overheads grow with r2
/// while the β terms are conserved), and `r1` cancels out of
/// `r1·m_a·ag·S / (T·r1·F)` — so no candidate in the row can exceed
/// `m_a·ag·S / (T·F(m_a, 1))`. Inflated by 1e-9 relative so
/// admissibility survives floating point (in the AG-bound regime the
/// bound is *tight* and the engine sums in a different order, within
/// ~1e-14 relative). A degenerate floor (≤ 0) returns `+inf`: never
/// prune on an all-zero model. Shared with
/// `solver::splitsearch::throughput_bound`, which additionally scales
/// by replicas and maximizes over memory-feasible `m_a`.
pub fn row_bound(sm: &StageModels, m_a: usize, ag: usize, seq_len: usize, n_layers: usize) -> f64 {
    let floor = Analytic::new(sm, m_a as f64, 1, 1).f;
    if floor <= 0.0 {
        return f64::INFINITY;
    }
    (m_a * ag * seq_len) as f64 / (n_layers as f64 * floor) * (1.0 + 1e-9)
}

/// One candidate probe, dispatched per [`EvalMode`].
fn probe(inst: &Instance, ev: &mut Evaluator, mode: EvalMode, cfg: PlanConfig) -> f64 {
    match mode {
        // The seed's exact per-candidate path: Instance::evaluate
        // re-derives StageModels and allocates a fresh DAG + SimResult.
        EvalMode::AllocPerCandidate => inst.evaluate(cfg).0,
        EvalMode::Buffered => ev.probe_makespan(cfg),
    }
}

/// Final (winner) evaluation: always exact on the engine.
fn final_eval(inst: &Instance, ev: &mut Evaluator, mode: EvalMode, cfg: PlanConfig) -> (f64, f64) {
    match mode {
        EvalMode::AllocPerCandidate => inst.evaluate(cfg),
        EvalMode::Buffered => ev.evaluate(cfg),
    }
}

/// Optimal r2 (and its makespan) for fixed (m_a, r1, order) via ternary
/// search over the convex-in-1/r2 objective. Returns (r2, m_e, makespan,
/// evals, engine_exact) — `engine_exact` is true when the winning probe
/// already ran on the discrete-event engine, so the caller can skip the
/// final re-simulation of the identical configuration.
///
/// In [`EvalMode::Buffered`] the integer ternary search memoizes probe
/// values per r2 (the search revisits midpoints, and its final ±2
/// plateau sweep re-walks points the narrowing loop already paid for);
/// `evals` counts only real probe evaluations, so
/// `benches/solver_speed.rs` can assert the memo drops the probe count
/// against the allocate-per-candidate baseline, which keeps the
/// original re-evaluating behaviour.
#[allow(clippy::too_many_arguments)]
fn best_r2(
    inst: &Instance,
    ev: &mut Evaluator,
    mode: EvalMode,
    m_a: usize,
    r1: usize,
    order: Order,
    fuse_shared: bool,
    r2_cap: usize,
) -> (usize, f64, f64, usize, bool) {
    let mut evals = 0usize;
    // Borrow, don't clone: token conservation only needs k (the last
    // per-candidate-group allocation in the solve loop — StageModels is
    // small but this path runs per (m_a, r1, order) visit).
    let k_tokens = ev.stage_models().k_tokens;
    let m_e_for = |r2: usize| k_tokens * m_a as f64 / r2 as f64;
    // m_e below one token per expert per part is degenerate; bound r2 so
    // that m_e >= 1.
    let max_r2 = (m_e_for(1).floor() as usize).clamp(1, r2_cap);
    let memoize = mode == EvalMode::Buffered;
    // Borrow the evaluator's scratch (capacity persists across calls)
    // instead of allocating a memo per (m_a, r1, order) visit; taken
    // out so the probe closure can still borrow `ev` mutably.
    let mut memo = std::mem::take(&mut ev.r2_memo);
    memo.clear();
    if memoize {
        memo.resize(max_r2 + 1, f64::NAN);
    }
    let mut eval = |r2: i64| -> f64 {
        let r2 = r2 as usize;
        if memoize && !memo[r2].is_nan() {
            return memo[r2];
        }
        evals += 1;
        let mut cfg = PlanConfig::findep(m_a, r1, r2, m_e_for(r2), order);
        cfg.fuse_shared = fuse_shared;
        let ms = probe(inst, ev, mode, cfg);
        if memoize {
            memo[r2] = ms;
        }
        ms
    };
    let (r2, makespan) = ternary_min_int(1, max_r2 as i64, &mut eval);
    ev.r2_memo = memo;
    let r2 = r2 as usize;
    let mut win = PlanConfig::findep(m_a, r1, r2, m_e_for(r2), order);
    win.fuse_shared = fuse_shared;
    let engine_exact = memoize && !ev.probe_is_analytic(&win);
    (r2, win.m_e, makespan, evals, engine_exact)
}

/// Warm variant of [`best_r2`]: certify or walk from the seed's `r2`
/// before falling back to the full ternary sweep. Under the same
/// unimodality premise the ternary search rests on (Thm 4), a point
/// with strictly-worse neighbors is *the* argmin, so a same-shape
/// re-solve certifies the seed in ≤ 3 probes instead of ~15; a strict
/// descent direction is walked up to [`PIVOT_WALK_CAP`] steps. Plateau
/// ties and exhausted walks fall back to the ternary sweep — whose
/// revisits of already-walked points cost nothing via the memo — so
/// the returned argmin always matches what a cold [`best_r2`] picks.
#[allow(clippy::too_many_arguments)]
fn best_r2_pivot(
    inst: &Instance,
    ev: &mut Evaluator,
    mode: EvalMode,
    m_a: usize,
    r1: usize,
    order: Order,
    r2_cap: usize,
    seed_r2: usize,
) -> (usize, f64, f64, usize, bool) {
    let mut evals = 0usize;
    let k_tokens = ev.stage_models().k_tokens;
    let m_e_for = |r2: usize| k_tokens * m_a as f64 / r2 as f64;
    let max_r2 = (m_e_for(1).floor() as usize).clamp(1, r2_cap);
    let memoize = mode == EvalMode::Buffered;
    let mut memo = std::mem::take(&mut ev.r2_memo);
    memo.clear();
    if memoize {
        memo.resize(max_r2 + 1, f64::NAN);
    }
    let mut eval = |r2: i64| -> f64 {
        let r2 = r2 as usize;
        if memoize && !memo[r2].is_nan() {
            return memo[r2];
        }
        evals += 1;
        let cfg = PlanConfig::findep(m_a, r1, r2, m_e_for(r2), order);
        let ms = probe(inst, ev, mode, cfg);
        if memoize {
            memo[r2] = ms;
        }
        ms
    };
    let hi_edge = max_r2 as i64;
    let mut cur = seed_r2.clamp(1, max_r2) as i64;
    let mut val = eval(cur);
    let lo = if cur > 1 { eval(cur - 1) } else { f64::INFINITY };
    let hi = if cur < hi_edge { eval(cur + 1) } else { f64::INFINITY };
    let mut settled = lo > val && hi > val;
    if !settled {
        // Strict descent only — a plateau tie is left to the ternary
        // sweep so the pick matches a cold solve's.
        let dir: i64 = if lo < val && lo <= hi {
            -1
        } else if hi < val {
            1
        } else {
            0
        };
        if dir != 0 {
            cur += dir;
            val = if dir < 0 { lo } else { hi };
            for _ in 0..PIVOT_WALK_CAP {
                let next = cur + dir;
                if next < 1 || next > hi_edge {
                    // Strict descent ended on the range boundary.
                    settled = true;
                    break;
                }
                let v = eval(next);
                if v < val {
                    cur = next;
                    val = v;
                } else if v > val {
                    // Strictly-worse neighbors on both sides (the walk
                    // arrived on strict descent).
                    settled = true;
                    break;
                } else {
                    break;
                }
            }
        }
    }
    let (r2, makespan) = if settled { (cur, val) } else { ternary_min_int(1, hi_edge, &mut eval) };
    ev.r2_memo = memo;
    let r2 = r2 as usize;
    let win = PlanConfig::findep(m_a, r1, r2, m_e_for(r2), order);
    let engine_exact = memoize && !ev.probe_is_analytic(&win);
    (r2, win.m_e, makespan, evals, engine_exact)
}

/// Accept a candidate only if it beats the incumbent with a real,
/// finite throughput — degenerate probes (0.0 or non-finite) never win.
fn improves(best: &Option<Solution>, tput: f64) -> bool {
    tput.is_finite()
        && tput > 0.0
        && best.as_ref().map_or(true, |b| tput > b.throughput_tokens)
}

/// Algorithm 1 (offline mode): maximize throughput over
/// (m_a, r1, r2, m_e, order) subject to memory. Buffered hot path.
pub fn solve(inst: &Instance, params: &SolverParams) -> Option<Solution> {
    solve_mode(inst, params, EvalMode::Buffered)
}

/// Algorithm 1 with an explicit evaluation mode (the
/// `AllocPerCandidate` baseline exists for the solver-speed bench).
pub fn solve_mode(inst: &Instance, params: &SolverParams, mode: EvalMode) -> Option<Solution> {
    solve_with(inst, params, mode, &mut inst.evaluator())
}

/// Algorithm 1 with a caller-held evaluator: the split search re-solves
/// one instance per (ag, eg) candidate, and passing one evaluator
/// across those solves keeps the plan/simulation arenas and the
/// engine's topology cache warm (candidate plans of different splits
/// share topologies and differ only in durations). The evaluator is
/// re-targeted at `inst` on entry, so any evaluator of the same model
/// family works.
pub fn solve_with(
    inst: &Instance,
    params: &SolverParams,
    mode: EvalMode,
    ev: &mut Evaluator,
) -> Option<Solution> {
    solve_warm(inst, params, mode, ev, None)
}

/// [`solve_with`] with an optional [`WarmStart`] seed — the serving
/// loop's warm re-solve entry. The returned winner is bit-identical to
/// the cold sweep for any soft seed (see the module docs); a hard
/// incumbent may yield `None` when nothing beats it.
pub fn solve_warm(
    inst: &Instance,
    params: &SolverParams,
    mode: EvalMode,
    ev: &mut Evaluator,
    warm: Option<&WarmStart>,
) -> Option<Solution> {
    ev.reset(inst);
    let mem = inst.memory();
    // Pareto rows, canonically m_a-descending: same r1 at a smaller
    // m_a loses by Thm 1.
    let mut rows: Vec<(usize, usize)> = Vec::new();
    if params.max_makespan.is_some() {
        // Goodput mode: the Pareto argument above only holds for the
        // throughput objective — a dominated row (smaller m_a or r1)
        // shortens the batch makespan and may be the only way under
        // the latency cap, so sweep every memory-feasible row.
        for m_a in (1..=params.ma_cap).rev() {
            for r1 in (1..=mem.get_max_r1(m_a, params.r1_cap)).rev() {
                rows.push((m_a, r1));
            }
        }
    } else {
        let mut prev_r1 = usize::MAX;
        for m_a in (1..=params.ma_cap).rev() {
            let r1 = mem.get_max_r1(m_a, params.r1_cap);
            if r1 == 0 || r1 == prev_r1 {
                continue;
            }
            prev_r1 = r1;
            rows.push((m_a, r1));
        }
    }
    sweep_rows(inst, params, mode, ev, &rows, warm)
}

/// Shared sweep core of the offline and online entries: evaluate the
/// given `(m_a, r1)` rows — already in canonical order — and reduce to
/// the best candidate.
///
/// The *visit* order may be permuted (warm seeding) and work may be
/// skipped (bound pruning, probe screening, budget expiry), but the
/// reduction always runs in canonical order with strict improvement,
/// so the winner — including exact-tie resolution — is independent of
/// visit order and identical to the legacy inline sweep's.
fn sweep_rows(
    inst: &Instance,
    params: &SolverParams,
    mode: EvalMode,
    ev: &mut Evaluator,
    rows: &[(usize, usize)],
    warm: Option<&WarmStart>,
) -> Option<Solution> {
    let t0 = Instant::now();
    if rows.is_empty() {
        return None;
    }
    // `Duration::MAX` (budget = ∞) overflows into `None`: no deadline,
    // bit-identical to an unbudgeted solve.
    let deadline = params.budget.and_then(|b| t0.checked_add(b));
    // Goodput mode: a candidate (or seed) only counts — toward the
    // results, the incumbent, or the pruning floor — when its batch
    // makespan meets the latency cap. `None` gates nothing and keeps
    // the sweep bit-identical to the pre-SLO solver.
    let within_cap = |ms: f64| params.max_makespan.map_or(true, |cap| ms <= cap);
    let has_shared = ev.stage_models().has_shared;
    let mut evals = 0usize;
    let mut pruned_rows = 0usize;
    let mut truncated = false;

    // Soft seed: prefer the exact (m_a, r1) row; otherwise pivot the
    // visit order around the nearest row by m_a (an online re-solve of
    // a drifted batch shape never contains the neighbor's exact row).
    let seed_cfg = warm.and_then(|w| {
        // Fused seeds sit outside the sweep's search space; ignore.
        if w.hard || w.config.map_or(false, |c| c.fuse_shared) {
            None
        } else {
            w.config
        }
    });
    let seed_exact =
        seed_cfg.and_then(|c| rows.iter().position(|&(m_a, r1)| m_a == c.m_a && r1 == c.r1));
    let seed_row = seed_cfg.map(|c| {
        seed_exact.unwrap_or_else(|| {
            let target = c.m_a as i64;
            (0..rows.len()).min_by_key(|&i| ((rows[i].0 as i64 - target).abs(), i)).unwrap()
        })
    });

    // The incumbent drives pruning and screening, so it must be a
    // value actually achieved on THIS instance (or a caller-vouched
    // hard floor): a soft seed is renormalized to this instance's
    // token conservation — its stored m_e (and numbers) may belong to
    // a neighboring shape — and re-evaluated here before it counts.
    let mut inc = warm.filter(|w| w.hard).map_or(0.0, |w| w.throughput_tokens);
    let mut seed_result: Option<(PlanConfig, f64, f64)> = None;
    if let (Some(c), Some(_)) = (seed_cfg, seed_exact) {
        let k_tokens = ev.stage_models().k_tokens;
        let max_r2 = ((k_tokens * c.m_a as f64).floor() as usize).clamp(1, params.r2_cap);
        let r2 = c.r2.clamp(1, max_r2);
        let cfg = PlanConfig::findep(c.m_a, c.r1, r2, k_tokens * c.m_a as f64 / r2 as f64, c.order);
        evals += 1;
        let (ms, tput) = final_eval(inst, ev, mode, cfg);
        if tput.is_finite() && tput > 0.0 && within_cap(ms) {
            if tput > inc {
                inc = tput;
            }
            seed_result = Some((cfg, ms, tput));
        }
    }

    let mut visit: Vec<usize> = (0..rows.len()).collect();
    if let Some(sr) = seed_row {
        let pivot_ma = rows[sr].0 as i64;
        visit.sort_by_key(|&i| ((rows[i].0 as i64 - pivot_ma).abs(), i));
    }

    let mut results: Vec<Vec<(PlanConfig, f64, f64)>> = vec![Vec::new(); rows.len()];
    let mut have_result = seed_result.is_some();
    for &ri in &visit {
        if let Some(d) = deadline {
            // Anytime truncation — but never before *something* is in
            // hand: a budgeted cold solve still covers ≥ 1 row.
            if have_result && Instant::now() >= d {
                truncated = true;
                break;
            }
        }
        let (m_a, r1) = rows[ri];
        if params.prune && inc > 0.0 {
            let bound = row_bound(ev.stage_models(), m_a, ev.ag, ev.seq_len, ev.n_layers);
            if bound < inc {
                pruned_rows += 1;
                continue;
            }
        }
        for order in Order::both() {
            // With no shared expert both orders coincide; skip AASS.
            if !has_shared && order == Order::Aass {
                continue;
            }
            let pivot = match (seed_cfg, seed_row) {
                (Some(c), Some(sr))
                    if params.prune && sr == ri && c.order == order && !c.fuse_shared =>
                {
                    Some(c.r2)
                }
                _ => None,
            };
            let (r2, m_e, ms, e, engine_exact) = match pivot {
                Some(p) => best_r2_pivot(inst, ev, mode, m_a, r1, order, params.r2_cap, p),
                None => best_r2(inst, ev, mode, m_a, r1, order, false, params.r2_cap),
            };
            evals += e;
            let cfg = PlanConfig::findep(m_a, r1, r2, m_e, order);
            // Engine-probed winners are already exact: reuse the
            // probe's makespan instead of re-simulating the identical
            // cfg.
            let (makespan, tput) = if engine_exact {
                (ms, ev.throughput_for(&cfg, ms))
            } else if let Some((_, sms, stput)) =
                seed_result.filter(|&(scfg, _, _)| scfg == cfg)
            {
                // The row search landed exactly on the seed config:
                // its engine-exact numbers are already paid for.
                (sms, stput)
            } else {
                let probe_tput = ev.throughput_for(&cfg, ms);
                if params.prune && inc > 0.0 && probe_tput < inc * (1.0 - SCREEN_EPS) {
                    // The probe value sits further below an achieved
                    // incumbent than the pinned analytic/engine
                    // agreement: the exact final evaluation cannot win.
                    continue;
                }
                evals += 1;
                final_eval(inst, ev, mode, cfg)
            };
            if tput.is_finite() && tput > 0.0 && within_cap(makespan) {
                results[ri].push((cfg, makespan, tput));
                have_result = true;
                if tput > inc {
                    inc = tput;
                }
            }
        }
    }

    // Canonical-order reduction: identical tie resolution to the
    // legacy inline sweep no matter how the visit order was permuted.
    let mut best: Option<Solution> = None;
    for row in &results {
        for &(cfg, makespan, tput) in row {
            if improves(&best, tput) {
                best = Some(Solution::candidate(cfg, makespan, tput));
            }
        }
    }
    // A (possibly truncated) warm sweep never returns worse than the
    // seed it started from: the re-evaluated seed is the floor. Strict
    // improvement keeps exact ties on the sweep's (= the cold) pick.
    if let Some((cfg, makespan, tput)) = seed_result {
        if best.as_ref().map_or(true, |b| tput > b.throughput_tokens) {
            best = Some(Solution::candidate(cfg, makespan, tput));
        }
    }
    best.map(|mut b| {
        b.solve_seconds = t0.elapsed().as_secs_f64();
        b.evals = evals;
        b.pruned_rows = pruned_rows;
        b.warm_seeded = warm.map_or(false, |w| w.config.is_some() && !w.hard);
        b.exhaustive = !truncated;
        b
    })
}

/// Online mode (§5.5): the batch is fixed by what arrived (total
/// `samples_per_gpu` samples per AG GPU); adapt `r1` (divisors of the
/// per-GPU batch), `r2`, and the order, with (ag, eg) pinned.
pub fn solve_online(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
) -> Option<Solution> {
    solve_online_mode(inst, samples_per_gpu, params, EvalMode::Buffered)
}

/// Online mode with an explicit evaluation mode.
pub fn solve_online_mode(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
    mode: EvalMode,
) -> Option<Solution> {
    solve_online_impl(inst, samples_per_gpu, params, mode, &[])
}

/// Online entry for the serving loop: like [`solve_online`], but `m_a`
/// restricted to `allowed_ma` — the coordinator's compiled attention
/// buckets, since the real executor can only launch bucket-exact
/// micro-batches. An empty slice places no restriction.
pub fn solve_online_bucketed(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
    allowed_ma: &[usize],
) -> Option<Solution> {
    solve_online_impl(inst, samples_per_gpu, params, EvalMode::Buffered, allowed_ma)
}

fn solve_online_impl(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
    mode: EvalMode,
    allowed_ma: &[usize],
) -> Option<Solution> {
    solve_online_with(inst, samples_per_gpu, params, mode, allowed_ma, None, &mut inst.evaluator())
}

/// Online mode with a caller-held evaluator and an optional
/// [`WarmStart`]: the serving loop re-solves shapes one KV bucket or
/// batch step apart at high rate, and rebuilding the arenas + topology
/// cache per call is pure overhead there (mirrors [`solve_with`];
/// `benches/solver_speed.rs` measures the drop). The evaluator is
/// re-targeted at `inst` on entry.
#[allow(clippy::too_many_arguments)]
pub fn solve_online_with(
    inst: &Instance,
    samples_per_gpu: usize,
    params: &SolverParams,
    mode: EvalMode,
    allowed_ma: &[usize],
    warm: Option<&WarmStart>,
    ev: &mut Evaluator,
) -> Option<Solution> {
    ev.reset(inst);
    let mem = inst.memory();
    if samples_per_gpu == 0 || mem.max_samples_per_ag_gpu() < samples_per_gpu {
        return None;
    }
    // Divisor rows in canonical r1-ascending (= m_a-descending) order.
    let rows: Vec<(usize, usize)> = (1..=params.r1_cap.min(samples_per_gpu))
        .filter(|r1| samples_per_gpu % r1 == 0)
        .map(|r1| (samples_per_gpu / r1, r1))
        .filter(|(m_a, _)| allowed_ma.is_empty() || allowed_ma.contains(m_a))
        .collect();
    sweep_rows(inst, params, mode, ev, &rows, warm)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst_deepseek(tb: Testbed) -> Instance {
        Instance::new(ModelConfig::deepseek_v2(8), tb, GroupSplit::new(3, 5), 2048)
    }

    fn inst_qwen(tb: Testbed) -> Instance {
        Instance::new(ModelConfig::qwen3_moe(12), tb, GroupSplit::new(4, 4), 2048)
    }

    #[test]
    fn solves_all_testbeds_quickly() {
        for tb in Testbed::all() {
            let inst = inst_deepseek(tb.clone());
            let sol = solve(&inst, &SolverParams::default()).expect("feasible");
            assert!(sol.throughput_tokens > 0.0);
            assert!(sol.solve_seconds < 1.0, "solver too slow: {}s", sol.solve_seconds);
            assert!(sol.config.r1 >= 1 && sol.config.r2 >= 1);
        }
    }

    #[test]
    fn qwen_without_shared_solves() {
        let sol = solve(&inst_qwen(Testbed::b()), &SolverParams::default()).unwrap();
        assert!(!sol.config.fuse_shared);
        assert!(sol.throughput_tokens > 0.0);
    }

    #[test]
    fn solution_beats_naive_and_trivial_configs() {
        let inst = inst_deepseek(Testbed::a());
        let sol = solve(&inst, &SolverParams::default()).unwrap();
        let sm = inst.stage_models();
        let naive = inst.evaluate(PlanConfig::naive(1, sm.m_e(1.0, 1))).1;
        assert!(
            sol.throughput_tokens >= naive,
            "solver {} < naive {}",
            sol.throughput_tokens,
            naive
        );
    }

    #[test]
    fn online_respects_batch() {
        let inst = inst_deepseek(Testbed::a());
        let sol = solve_online(&inst, 8, &SolverParams::default()).unwrap();
        assert_eq!(sol.config.m_a * sol.config.r1, 8);
        // Huge batches that don't fit must be rejected.
        assert!(solve_online(&inst, 10_000_000, &SolverParams::default()).is_none());
    }

    #[test]
    fn online_bucketed_restricts_ma() {
        let inst = inst_deepseek(Testbed::a());
        let params = SolverParams::default();
        // Restricting to a single bucket pins m_a.
        let sol = solve_online_bucketed(&inst, 8, &params, &[2]).unwrap();
        assert_eq!(sol.config.m_a, 2);
        assert_eq!(sol.config.r1, 4);
        // The unrestricted entry agrees with solve_online exactly.
        let a = solve_online_bucketed(&inst, 8, &params, &[]).unwrap();
        let b = solve_online(&inst, 8, &params).unwrap();
        assert_eq!(a.config, b.config);
        assert_eq!(a.throughput_tokens, b.throughput_tokens);
        // No bucket divides the batch -> infeasible.
        assert!(solve_online_bucketed(&inst, 9, &params, &[2, 4]).is_none());
    }

    #[test]
    fn decode_phase_solves_per_phase_plans() {
        // Decode on the paper instance: token conservation at one token
        // per sample makes m_e < 1, so the fine-grained split collapses
        // to r2 = 1 — while the prefill solve of the same (model,
        // testbed, split) keeps r2 > 1. The two phases genuinely need
        // different plans (the premise of phase-keyed caching).
        let params = SolverParams::default();
        let dec = Instance::decode(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        );
        let d = solve(&dec, &params).expect("decode feasible");
        assert_eq!(d.config.r2, 1, "decode m_e < 1 token must force r2 = 1");
        assert!(d.throughput_tokens > 0.0);
        let p = solve(&inst_deepseek(Testbed::a()), &params).unwrap();
        assert!(p.config.r2 > 1, "prefill keeps fine-grained parts");
        assert_ne!(p.config, d.config);
        // Online decode mode respects the arriving batch.
        let o = solve_online(&dec, 8, &params).expect("online decode feasible");
        assert_eq!(o.config.m_a * o.config.r1, 8);
    }

    #[test]
    #[should_panic(expected = "zero-length sequence")]
    fn zero_seq_len_rejected_at_solve_boundary() {
        let _ = Instance::new(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            0,
        );
    }

    #[test]
    fn infeasible_split_returns_none() {
        // All experts on one 24 GB device: infeasible.
        let inst = Instance::new(
            ModelConfig::deepseek_v2(8),
            Testbed::b(),
            GroupSplit::new(7, 1),
            2048,
        );
        assert!(solve(&inst, &SolverParams::default()).is_none());
    }

    #[test]
    fn buffered_and_alloc_modes_agree() {
        // The arena + closed-form path is a de-allocation, not a
        // different search. Tolerance bound: the closed forms match the
        // engine to 1e-9 relative (pinned by simulator_vs_analytic), so
        // a probe can only flip the chosen r2 where two candidates'
        // makespans tie within that tolerance — and two candidates that
        // tie on makespan differ in final engine throughput by at most
        // the same relative order. Hence both modes must land within
        // 1e-9 relative throughput of each other (empirically they are
        // bit-identical on every paper instance).
        let params = SolverParams::default();
        for tb in Testbed::all() {
            for inst in [inst_deepseek(tb.clone()), inst_qwen(tb.clone())] {
                let buffered = solve_mode(&inst, &params, EvalMode::Buffered);
                let alloc = solve_mode(&inst, &params, EvalMode::AllocPerCandidate);
                match (buffered, alloc) {
                    (Some(b), Some(a)) => {
                        let rel = (b.throughput_tokens - a.throughput_tokens).abs()
                            / a.throughput_tokens;
                        assert!(
                            rel <= 1e-9,
                            "throughput drift on {}: buffered {} vs alloc {} (rel {rel:e}, \
                             buffered cfg {:?}, alloc cfg {:?})",
                            inst.cluster.name,
                            b.throughput_tokens,
                            a.throughput_tokens,
                            b.config,
                            a.config
                        );
                    }
                    (None, None) => {}
                    (b, a) => panic!(
                        "feasibility drift on {}: buffered={} alloc={}",
                        inst.cluster.name,
                        b.is_some(),
                        a.is_some()
                    ),
                }
            }
        }
    }

    #[test]
    fn solve_with_shared_evaluator_is_bit_identical() {
        // One evaluator carried across instances (the split-search hot
        // path: warm arenas + topology cache) must reproduce the
        // fresh-evaluator solve exactly, bit for bit.
        let params = SolverParams::default();
        let mut ev = inst_deepseek(Testbed::a()).evaluator();
        for tb in Testbed::all() {
            for inst in [inst_deepseek(tb.clone()), inst_qwen(tb.clone())] {
                let fresh = solve(&inst, &params);
                let shared = solve_with(&inst, &params, EvalMode::Buffered, &mut ev);
                match (fresh, shared) {
                    (Some(f), Some(s)) => {
                        assert_eq!(f.config, s.config, "config drift on {}", inst.cluster.name);
                        assert_eq!(f.throughput_tokens, s.throughput_tokens);
                        assert_eq!(f.makespan, s.makespan);
                        assert_eq!(f.evals, s.evals);
                    }
                    (None, None) => {}
                    (f, s) => panic!(
                        "feasibility drift on {}: fresh={} shared={}",
                        inst.cluster.name,
                        f.is_some(),
                        s.is_some()
                    ),
                }
            }
        }
        // The shared evaluator actually exercised the topology cache.
        assert!(ev.topo_hits() > 0, "expected duration-only fast-path hits across instances");
    }

    #[test]
    fn memoized_ternary_probes_fewer_candidates() {
        // The Buffered path memoizes revisited r2 probes and skips the
        // winner's redundant final simulation; the alloc baseline keeps
        // the original counting. On every feasible paper-shaped
        // instance the probe count must strictly drop.
        let params = SolverParams::default();
        for tb in Testbed::all() {
            let inst = inst_deepseek(tb.clone());
            let (Some(b), Some(a)) = (
                solve_mode(&inst, &params, EvalMode::Buffered),
                solve_mode(&inst, &params, EvalMode::AllocPerCandidate),
            ) else {
                continue;
            };
            assert!(
                b.evals < a.evals,
                "probe count did not drop on {}: buffered {} vs alloc {}",
                inst.cluster.name,
                b.evals,
                a.evals
            );
        }
    }

    #[test]
    fn prune_off_oracle_is_bit_identical() {
        // prune=true may only skip provably-losing work: winner,
        // throughput, and makespan must match the unpruned oracle bit
        // for bit, at no more evaluations.
        let pruned = SolverParams::default();
        let oracle = SolverParams { prune: false, ..pruned };
        let mut insts: Vec<Instance> = Vec::new();
        for tb in Testbed::all() {
            insts.push(inst_deepseek(tb.clone()));
            insts.push(inst_qwen(tb.clone()));
        }
        insts.push(Instance::decode(
            ModelConfig::deepseek_v2(8),
            Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        ));
        for inst in &insts {
            match (solve(inst, &pruned), solve(inst, &oracle)) {
                (Some(p), Some(o)) => {
                    assert_eq!(p.config, o.config, "winner drift on {}", inst.cluster.name);
                    assert_eq!(p.throughput_tokens, o.throughput_tokens);
                    assert_eq!(p.makespan, o.makespan);
                    assert!(p.evals <= o.evals);
                    assert_eq!(o.pruned_rows, 0, "the oracle must not prune");
                    assert!(p.exhaustive && o.exhaustive);
                }
                (None, None) => {}
                (p, o) => {
                    panic!("feasibility drift: pruned={} oracle={}", p.is_some(), o.is_some())
                }
            }
        }
    }

    #[test]
    fn warm_resolve_is_bit_identical_and_cheaper() {
        // Re-solving a shape from its own solution: same winner, same
        // numbers, strictly fewer evaluations (seed certification
        // replaces the winner row's ternary sweep).
        let params = SolverParams::default();
        for tb in Testbed::all() {
            for inst in [inst_deepseek(tb.clone()), inst_qwen(tb.clone())] {
                let Some(cold) = solve(&inst, &params) else { continue };
                let warm = WarmStart::from_solution(&cold);
                let mut ev = inst.evaluator();
                let w = solve_warm(&inst, &params, EvalMode::Buffered, &mut ev, Some(&warm))
                    .expect("warm solve feasible where cold was");
                assert_eq!(w.config, cold.config, "warm winner drift on {}", inst.cluster.name);
                assert_eq!(w.throughput_tokens, cold.throughput_tokens);
                assert_eq!(w.makespan, cold.makespan);
                assert!(w.warm_seeded && w.exhaustive);
                assert!(
                    w.evals < cold.evals,
                    "warm evals {} !< cold {} on {}",
                    w.evals,
                    cold.evals,
                    inst.cluster.name
                );
            }
        }
    }

    #[test]
    fn budget_semantics() {
        let inst = inst_deepseek(Testbed::a());
        let base = SolverParams::default();
        let cold = solve(&inst, &base).unwrap();
        // budget = ∞: bit-identical to the unbudgeted solve, evals
        // included.
        let inf = SolverParams { budget: Some(Duration::MAX), ..base };
        let i = solve(&inst, &inf).unwrap();
        assert_eq!(i.config, cold.config);
        assert_eq!(i.throughput_tokens, cold.throughput_tokens);
        assert_eq!(i.evals, cold.evals);
        assert!(i.exhaustive);
        // budget → 0 with a warm seed: the seed comes back unchanged,
        // flagged non-exhaustive.
        let zero = SolverParams { budget: Some(Duration::ZERO), ..base };
        let warm = WarmStart::from_solution(&cold);
        let mut ev = inst.evaluator();
        let z = solve_warm(&inst, &zero, EvalMode::Buffered, &mut ev, Some(&warm)).unwrap();
        assert_eq!(z.config, cold.config);
        assert_eq!(z.throughput_tokens, cold.throughput_tokens);
        assert!(!z.exhaustive);
        // budget → 0 cold still returns a plan (progress guarantee:
        // at least one row is always evaluated).
        let zc = solve(&inst, &zero).unwrap();
        assert!(zc.throughput_tokens > 0.0);
    }

    #[test]
    fn hard_incumbent_prunes_or_preserves() {
        let inst = inst_deepseek(Testbed::a());
        let params = SolverParams::default();
        let cold = solve(&inst, &params).unwrap();
        let mut ev = inst.evaluator();
        // A floor above everything achievable: nothing beats it.
        let hi = WarmStart::incumbent(cold.throughput_tokens * 2.0);
        assert!(solve_warm(&inst, &params, EvalMode::Buffered, &mut ev, Some(&hi)).is_none());
        // A floor below the optimum: winner bit-identical to cold.
        let lo = WarmStart::incumbent(cold.throughput_tokens * 0.5);
        let s = solve_warm(&inst, &params, EvalMode::Buffered, &mut ev, Some(&lo)).unwrap();
        assert_eq!(s.config, cold.config);
        assert_eq!(s.throughput_tokens, cold.throughput_tokens);
        assert!(!s.warm_seeded, "a bare incumbent is not a seed");
    }

    #[test]
    fn online_with_shared_evaluator_and_warm_matches() {
        let inst = inst_deepseek(Testbed::a());
        let params = SolverParams::default();
        let cold = solve_online(&inst, 8, &params).unwrap();
        let mut ev = inst.evaluator();
        let shared =
            solve_online_with(&inst, 8, &params, EvalMode::Buffered, &[], None, &mut ev).unwrap();
        assert_eq!(shared.config, cold.config);
        assert_eq!(shared.throughput_tokens, cold.throughput_tokens);
        assert_eq!(shared.evals, cold.evals);
        // Warm-seeded from its own solution: same winner, fewer evals.
        let warm = WarmStart::from_solution(&cold);
        let w = solve_online_with(&inst, 8, &params, EvalMode::Buffered, &[], Some(&warm), &mut ev)
            .unwrap();
        assert_eq!(w.config, cold.config);
        assert_eq!(w.throughput_tokens, cold.throughput_tokens);
        assert!(w.evals < cold.evals);
        assert!(w.warm_seeded);
        // A neighbor seed (different batch, so its exact row does not
        // exist here) still reproduces the cold winner bit for bit:
        // seeds are renormalized and re-evaluated on the target
        // instance, never trusted.
        let cold4 = solve_online(&inst, 4, &params).unwrap();
        let nw = WarmStart::from_solution(&cold4);
        let n = solve_online_with(&inst, 8, &params, EvalMode::Buffered, &[], Some(&nw), &mut ev)
            .unwrap();
        assert_eq!(n.config, cold.config);
        assert_eq!(n.throughput_tokens, cold.throughput_tokens);
    }

    #[test]
    fn slo_cap_none_and_infinite_match_uncapped_bitwise() {
        for inst in [inst_deepseek(Testbed::a()), inst_qwen(Testbed::b())] {
            let base = SolverParams::default();
            let cold = solve(&inst, &base).unwrap();
            let inf = SolverParams { max_makespan: Some(f64::INFINITY), ..base };
            let s = solve(&inst, &inf).unwrap();
            assert_eq!(s.config, cold.config);
            assert_eq!(s.throughput_tokens.to_bits(), cold.throughput_tokens.to_bits());
            assert_eq!(s.makespan.to_bits(), cold.makespan.to_bits());
        }
    }

    #[test]
    fn slo_cap_trades_throughput_for_latency() {
        let inst = inst_deepseek(Testbed::a());
        let base = SolverParams::default();
        let cold = solve(&inst, &base).unwrap();
        // Cap just below the throughput-optimal plan's makespan: the
        // goodput winner must be a different, faster, lower-throughput
        // plan that honors the cap.
        let cap = cold.makespan * 0.5;
        let capped =
            solve(&inst, &SolverParams { max_makespan: Some(cap), ..base }).expect("feasible cap");
        assert!(capped.makespan <= cap, "{} > {}", capped.makespan, cap);
        assert!(capped.throughput_tokens <= cold.throughput_tokens);
        assert_ne!(capped.config, cold.config, "tight cap must move the winner");
        // Every plan meeting the cap is dominated by the capped winner:
        // the uncapped winner at the capped winner's own makespan would
        // have been kept. Sanity: the capped winner still does real work.
        assert!(capped.throughput_tokens > 0.0);
        // An impossible cap yields no plan at all.
        assert!(solve(&inst, &SolverParams { max_makespan: Some(1e-12), ..base }).is_none());
    }

    #[test]
    fn slo_cap_online_respects_batch_and_cap() {
        let inst = inst_deepseek(Testbed::a());
        let base = SolverParams::default();
        let cold = solve_online(&inst, 8, &base).unwrap();
        let cap = cold.makespan * 0.75;
        match solve_online(&inst, 8, &SolverParams { max_makespan: Some(cap), ..base }) {
            Some(s) => {
                assert_eq!(s.config.m_a * s.config.r1, 8);
                assert!(s.makespan <= cap);
                assert!(s.throughput_tokens <= cold.throughput_tokens);
            }
            // A fixed batch may simply not fit under the cap.
            None => {}
        }
    }

    #[test]
    fn slo_cap_warm_seed_violating_cap_is_discarded() {
        let inst = inst_deepseek(Testbed::a());
        let base = SolverParams::default();
        let cold = solve(&inst, &base).unwrap();
        let cap = cold.makespan * 0.5;
        let capped_params = SolverParams { max_makespan: Some(cap), ..base };
        let capped = solve(&inst, &capped_params).unwrap();
        // Seed the capped solve with the cap-violating uncapped winner:
        // the seed must not leak through as a result.
        let mut ev = inst.evaluator();
        let warm = WarmStart::from_solution(&cold);
        let w = solve_warm(&inst, &capped_params, EvalMode::Buffered, &mut ev, Some(&warm))
            .expect("capped solve stays feasible under a bad seed");
        assert!(w.makespan <= cap);
        assert_eq!(w.config, capped.config);
        assert_eq!(w.throughput_tokens.to_bits(), capped.throughput_tokens.to_bits());
    }

    #[test]
    fn evaluator_matches_one_shot_instance_evaluate() {
        let inst = inst_deepseek(Testbed::a());
        let sm = inst.stage_models();
        let mut ev = inst.evaluator();
        for (m_a, r1, r2, order) in
            [(1usize, 1usize, 1usize, Order::Asas), (2, 2, 4, Order::Aass), (4, 2, 8, Order::Asas)]
        {
            let cfg = PlanConfig::findep(m_a, r1, r2, sm.m_e(m_a as f64, r2), order);
            let (ms_a, tp_a) = inst.evaluate(cfg);
            let (ms_b, tp_b) = ev.evaluate(cfg);
            assert_eq!(ms_a, ms_b);
            assert_eq!(tp_a, tp_b);
            // The ASAS probe shortcut agrees with the engine exactly.
            if order == Order::Asas {
                assert!(
                    (ev.probe_makespan(cfg) - ms_a).abs() <= 1e-9 * ms_a,
                    "closed-form probe drifted from engine"
                );
            }
        }
    }
}
