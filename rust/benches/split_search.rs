//! Split-search speed: the pruned + parallel + topology-reusing
//! [`findep::solver::splitsearch`] layer against the serial
//! cold-solve-per-split sweep it replaced (the pre-existing
//! `benches/ablations.rs` behaviour).
//!
//! For every paper instance the two searches must return the identical
//! winning (placement, PlanConfig, throughput) — bit for bit — and the
//! optimised search must be strictly faster in aggregate (asserted in
//! quick mode too: pruning skips whole Algorithm-1 solves, so the
//! margin does not depend on timer resolution).
//!
//! Emits a `BENCH_splitsearch.json` trajectory file.
//!
//! Run: `cargo bench --bench split_search`

use findep::config::{ModelConfig, Testbed};
use findep::solver::{search_splits, search_splits_serial, SearchParams};
use findep::util::bench::{fmt_duration, Bencher, Table};
use findep::util::json::{to_string_pretty, Json, JsonObj};

fn paper_cases() -> Vec<(String, ModelConfig, Testbed, usize)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for (deepseek, name) in [(true, "deepseek"), (false, "qwen")] {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            out.push((format!("{name}/{}", tb.name), model, tb.clone(), 4096));
        }
    }
    out
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let params = SearchParams::default();

    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("split_search".into()));
    report.insert("quick", Json::Bool(quick));

    let mut table = Table::new(
        "Split search: serial cold sweep vs pruned+parallel+topology-reuse",
        &["instance", "cands", "solved", "pruned", "serial", "search", "speedup", "winner"],
    );
    let (mut sum_serial, mut sum_search) = (0.0f64, 0.0f64);
    let mut entries: Vec<Json> = Vec::new();
    for (label, model, tb, seq) in paper_cases() {
        // Correctness gate first: identical winning (split, PlanConfig,
        // throughput) — bit for bit — before any timing.
        let serial = search_splits_serial(&model, &tb, seq, &params);
        let searched = search_splits(&model, &tb, seq, &params);
        let (serial_best, rep) = match (serial, searched) {
            (Some(s), Some(o)) => (s, o),
            (None, None) => continue,
            (s, o) => panic!(
                "feasibility disagreement on {label}: serial={} search={}",
                s.is_some(),
                o.is_some()
            ),
        };
        assert_eq!(
            serial_best.candidate, rep.best.candidate,
            "winning placement differs on {label}"
        );
        assert_eq!(
            serial_best.per_instance.config, rep.best.per_instance.config,
            "winning PlanConfig differs on {label}"
        );
        assert_eq!(
            serial_best.per_instance.throughput_tokens, rep.best.per_instance.throughput_tokens,
            "winning per-instance throughput differs on {label}"
        );
        assert_eq!(
            serial_best.total_throughput, rep.best.total_throughput,
            "winning total throughput differs on {label}"
        );

        let r_serial = bencher.run(&format!("{label}/serial"), || {
            let _ = search_splits_serial(&model, &tb, seq, &params);
        });
        let r_search = bencher.run(&format!("{label}/search"), || {
            let _ = search_splits(&model, &tb, seq, &params);
        });
        sum_serial += r_serial.mean_s();
        sum_search += r_search.mean_s();
        let st = &rep.stats;
        table.row(&[
            label.clone(),
            st.candidates.to_string(),
            st.solved.to_string(),
            st.pruned.to_string(),
            fmt_duration(r_serial.mean_s()),
            fmt_duration(r_search.mean_s()),
            format!("{:.2}x", r_serial.mean_s() / r_search.mean_s()),
            format!("{} {:.0} tok/s", rep.best.candidate.describe(), rep.best.total_throughput),
        ]);
        let mut e = JsonObj::new();
        e.insert("instance", Json::Str(label));
        e.insert("candidates", Json::Num(st.candidates as f64));
        e.insert("solved", Json::Num(st.solved as f64));
        e.insert("pruned", Json::Num(st.pruned as f64));
        e.insert("infeasible", Json::Num(st.infeasible as f64));
        e.insert("threads", Json::Num(st.threads as f64));
        e.insert("serial_mean_s", Json::Num(r_serial.mean_s()));
        e.insert("search_mean_s", Json::Num(r_search.mean_s()));
        e.insert("speedup", Json::Num(r_serial.mean_s() / r_search.mean_s()));
        e.insert("winner_replicas", Json::Num(rep.best.candidate.replicas as f64));
        e.insert("winner_ag", Json::Num(rep.best.candidate.split.ag as f64));
        e.insert("winner_eg", Json::Num(rep.best.candidate.split.eg as f64));
        e.insert("winner_config", Json::Str(rep.best.per_instance.config.describe()));
        e.insert("winner_total_tokens_per_s", Json::Num(rep.best.total_throughput));
        entries.push(Json::Obj(e));
    }
    table.print();
    println!(
        "aggregate split-search wall time: serial {} vs optimized {} -> {:.2}x",
        fmt_duration(sum_serial),
        fmt_duration(sum_search),
        sum_serial / sum_search
    );
    // The acceptance gate: the enlarged search must be strictly faster
    // than the serial cold-solve-per-split sweep of the same space.
    assert!(
        sum_search < sum_serial,
        "pruned+parallel+topology-reuse search ({sum_search:.6}s) must beat the serial \
         cold sweep ({sum_serial:.6}s)"
    );
    report.insert("instances", Json::Arr(entries));
    report.insert("aggregate_serial_s", Json::Num(sum_serial));
    report.insert("aggregate_search_s", Json::Num(sum_search));
    report.insert("aggregate_speedup", Json::Num(sum_serial / sum_search));
    std::fs::write("BENCH_splitsearch.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_splitsearch.json");
    println!("wrote BENCH_splitsearch.json");
}
