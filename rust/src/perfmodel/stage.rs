//! Component models (t_gm, t_attn, t_c) and the derived per-stage layer
//! models of §4.1.
//!
//! Workload conventions follow the paper exactly:
//! * GEMM workload `x = m·k·n` (the product of dimensions, *not* 2mkn).
//! * Attention workload `y = n_h·B·S²·(d_k + d_v)`.
//! * Communication workload `z` = bytes per machine.
//!
//! Derived coefficients (Eqs. 10-11 and the following paragraphs):
//! * `t_a(m_a)  = α_a + β_a·m_a`, α_a = 4α_gm + α_attn,
//!   β_a = β_gm·(2·S·M·n_h·d_k + 2·S·M·n_h·d_v) + β_attn·S²·n_h·(d_k+d_v)
//! * `t_s(m_a)  = α_s + β_s·m_a`, α_s = 3·N_shared·α_gm,
//!   β_s = 3·N_shared·β_gm·S·M·H
//! * `t_e(m_e)  = α_e + β_e·m_e`, α_e = 3·(E/eg)·α_gm,
//!   β_e = 3·(E/eg)·β_gm·M·H   (we keep the factor 3 in α_e that Eq. 3
//!   implies; the paper's prose drops it — a typo that only shifts the
//!   constant)
//! * `t_a2e(m_e) = α_c + β_c·(E/eg)·m_e·M·bytes`, and t_e2a = t_a2e
//!   (full-duplex symmetric links, §3.1).

use crate::config::{Cluster, ExpertLoad, ExpertPlacement, GroupSplit, ModelConfig, Phase, Testbed};
use crate::perfmodel::linear::LinearModel;

/// The three hardware component models fitted by micro-benchmarks
/// (§5.2 / Fig. 7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompModels {
    /// GEMM: seconds vs FLOPs (product m·k·n).
    pub gemm: LinearModel,
    /// Attention: seconds vs y = n_h·B·S²·(d_k+d_v).
    pub attn: LinearModel,
    /// Transfer: seconds vs bytes per machine.
    pub comm: LinearModel,
}

impl CompModels {
    /// Derive component models from a testbed's effective constants.
    ///
    /// The communication β folds in the inter-group fan-out: each of the
    /// `ag` senders pushes its payload across a bisection of width
    /// `min(ag, eg)` links, so effective per-byte cost scales by
    /// `ag / min(ag, eg)` — this reproduces the (eg,ag)-dependent slopes
    /// of Fig. 7b.
    pub fn from_testbed(tb: &Testbed, split: GroupSplit) -> Self {
        let fanout = split.ag as f64 / (split.ag.min(split.eg) as f64);
        Self {
            gemm: LinearModel::new(tb.alpha_comp_s, 1.0 / tb.gemm_flops),
            attn: LinearModel::new(tb.alpha_attn_s, 1.0 / tb.attn_flops),
            comm: LinearModel::new(tb.alpha_comm_s, fanout / tb.link_bw),
        }
    }

    /// Component models driven by a calibration profile's measured
    /// constants instead of the hand-written Table-2 values: the
    /// profile is substituted into `base` via [`Testbed::from_profile`]
    /// and run through the *same* derivation, so the calibrated and
    /// hand-constant paths cannot drift — identical constants produce
    /// bit-identical models.
    pub fn from_profile(
        profile: &crate::perfmodel::profile::CalibrationProfile,
        base: &Testbed,
        split: GroupSplit,
    ) -> Self {
        Self::from_testbed(&Testbed::from_profile(base, profile), split)
    }
}

/// Cluster-aware component models: the heterogeneous generalization of
/// [`CompModels`]. The attention pool contributes the projection-GEMM
/// and attention-kernel models (shared experts also run on AG devices,
/// so `gemm_a` covers them too — see `solver::memory`, which charges
/// their weights against AG capacity), the expert pool contributes the
/// FFN GEMM model, and the cross-pool [`crate::config::M2nModel`]
/// contributes the transfer model with the Fig.-7b fan-out folded in.
/// On a single-pool cluster `gemm_a == gemm_e` and the M2N collapses to
/// the pool's own link scalars, making every derived coefficient
/// bit-identical to [`CompModels::from_testbed`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterComps {
    /// Attention-pool GEMM (Q/K/V/O projections + shared experts).
    pub gemm_a: LinearModel,
    /// Attention-pool attention kernel.
    pub attn: LinearModel,
    /// Expert-pool GEMM (expert FFN stack).
    pub gemm_e: LinearModel,
    /// Cross-pool M2N transfer, per byte per machine.
    pub comm: LinearModel,
}

impl ClusterComps {
    pub fn from_cluster(cl: &Cluster, split: GroupSplit) -> Self {
        let fanout = split.ag as f64 / (split.ag.min(split.eg) as f64);
        let a = &cl.attn().gpu;
        let e = &cl.expert().gpu;
        let m2n = cl.m2n();
        Self {
            gemm_a: LinearModel::new(a.alpha_comp_s, 1.0 / a.gemm_flops),
            attn: LinearModel::new(a.alpha_attn_s, 1.0 / a.attn_flops),
            gemm_e: LinearModel::new(e.alpha_comp_s, 1.0 / e.gemm_flops),
            comm: LinearModel::new(m2n.alpha_s, fanout / m2n.bw),
        }
    }

    /// The homogeneous special case: one GEMM model serves both roles.
    pub fn from_comp_models(comp: &CompModels) -> Self {
        Self { gemm_a: comp.gemm, attn: comp.attn, gemm_e: comp.gemm, comm: comp.comm }
    }
}

/// Projection-GEMM workload scale per attention flavour: MLA's Q/KV
/// projections factor through low-rank latents (DeepSeek-V2: q_lora
/// 1536, c_KV 512+64), which cuts the projection workload to roughly
/// 0.35x of the equivalent full-rank MHA projections. One table shared
/// by the prefill and decode derivations so a recalibration cannot
/// diverge the phases.
fn proj_factor(model: &ModelConfig) -> f64 {
    match model.attention {
        crate::config::AttentionKind::Mha => 1.0,
        crate::config::AttentionKind::Mla => 0.35,
    }
}

/// Per-stage layer models for a concrete (model, testbed, split, S).
///
/// All four stage times are linear in their micro-batch variable; this
/// struct is the entire interface between hardware and the scheduler —
/// both the analytic objective (Eq. 13) and the discrete-event simulator
/// consume stage durations from here.
#[derive(Debug, Clone, PartialEq)]
pub struct StageModels {
    /// Attention stage vs m_a (samples per AG GPU per micro-batch).
    pub t_a: LinearModel,
    /// Shared-expert stage vs m_a. Zero-duration when N_shared = 0.
    pub t_s: LinearModel,
    /// Expert FFN stage vs m_e (tokens per expert per fine-grained part).
    pub t_e: LinearModel,
    /// A2E (== E2A) transfer vs m_e.
    pub t_a2e: LinearModel,
    /// Token-conservation ratio k: m_e = k/r2 · m_a (from
    /// m_a·ag·top_k·S = m_e·r2·E, Theorem 1).
    pub k_tokens: f64,
    pub has_shared: bool,
}

impl StageModels {
    pub fn new(model: &ModelConfig, tb: &Testbed, split: GroupSplit, seq_len: usize) -> Self {
        let comp = CompModels::from_testbed(tb, split);
        Self::from_components(model, &comp, split, seq_len)
    }

    /// Phase-aware constructor: prefill keeps the Eqs. 10-11 derivation
    /// at `S = seq_len`; decode re-derives every coefficient for the
    /// autoregressive regime (one token per sample, KV-read-bound
    /// attention at the testbed's HBM bandwidth). The struct shape is
    /// identical either way — the phase is baked into the α/β
    /// coefficients and `k_tokens`, so everything downstream (plans,
    /// analytic closed forms, simulator, Algorithm 1) is phase-agnostic.
    pub fn for_phase(
        model: &ModelConfig,
        tb: &Testbed,
        split: GroupSplit,
        seq_len: usize,
        phase: Phase,
    ) -> Self {
        let comp = CompModels::from_testbed(tb, split);
        match phase {
            Phase::Prefill => Self::from_components(model, &comp, split, seq_len),
            Phase::Decode { kv_len } => Self::decode_from_components(
                model,
                &comp,
                split,
                kv_len,
                LinearModel::new(0.0, 1.0 / tb.hbm_bw),
            ),
        }
    }

    /// Cluster-aware constructor: the heterogeneous generalization of
    /// [`Self::for_phase`]. Attention-group coefficients (projection
    /// GEMMs, attention kernel, shared experts, decode KV streaming)
    /// come from the attention pool, expert-FFN coefficients from the
    /// expert pool, and the transfer model from the cross-pool M2N.
    /// For a [`Cluster::single_pool`] this performs literally the same
    /// arithmetic as `for_phase` on the underlying testbed — the
    /// refactor's bit-identity oracle (`tests/cluster_equivalence.rs`).
    pub fn for_cluster(
        model: &ModelConfig,
        cl: &Cluster,
        split: GroupSplit,
        seq_len: usize,
        phase: Phase,
    ) -> Self {
        Self::for_cluster_placed(
            model,
            cl,
            split,
            seq_len,
            phase,
            &ExpertPlacement::uniform(model.n_experts, split.eg),
            &ExpertLoad::uniform(model.n_experts),
        )
    }

    /// Placement-aware generalization of [`Self::for_cluster`]: the
    /// expert-stage α/β come from the **max-loaded shard** of
    /// `placement` under `load` instead of the uniform `(E/eg)·m_e`
    /// closed form. The uniform placement short-circuits to the literal
    /// `E/eg` expressions, so `for_cluster` (which delegates here) is
    /// bit-identical to the legacy derivation — pinned by
    /// `tests/placement_equivalence.rs`.
    pub fn for_cluster_placed(
        model: &ModelConfig,
        cl: &Cluster,
        split: GroupSplit,
        seq_len: usize,
        phase: Phase,
        placement: &ExpertPlacement,
        load: &ExpertLoad,
    ) -> Self {
        let comp = ClusterComps::from_cluster(cl, split);
        match phase {
            Phase::Prefill => {
                Self::from_cluster_comps_placed(model, &comp, split, seq_len, placement, load)
            }
            Phase::Decode { kv_len } => Self::decode_from_cluster_comps_placed(
                model,
                &comp,
                split,
                kv_len,
                LinearModel::new(0.0, 1.0 / cl.attn().gpu.hbm_bw),
                placement,
                load,
            ),
        }
    }

    /// Decode-phase stage models: one generated token per sample per
    /// forward pass. Relative to the prefill derivation (Eqs. 10-11 at
    /// `S = 1`), the only structural change is the attention term —
    /// instead of the `S²` score workload, each sample streams its
    /// `kv_len + 1` resident KV entries (the cache plus this step's
    /// write) per layer, so the cost is the *max* of the score FLOPs at
    /// that KV length and the KV bytes through `kv_read` (seconds per
    /// byte of device memory). On every paper testbed the byte term
    /// dominates by orders of magnitude: decode attention is
    /// memory-bound. Expert/shared GEMMs and the A2E transfer keep
    /// their per-token coefficients; token conservation becomes
    /// `m_a·ag·top_k·1 = m_e·r2·E`, shrinking `m_e` to roughly one
    /// token per expert — which is why decode optima collapse to
    /// `r2 = 1` (per-part launch overhead dwarfs the β terms).
    pub fn decode_from_components(
        model: &ModelConfig,
        comp: &CompModels,
        split: GroupSplit,
        kv_len: usize,
        kv_read: LinearModel,
    ) -> Self {
        Self::decode_from_cluster_comps(
            model,
            &ClusterComps::from_comp_models(comp),
            split,
            kv_len,
            kv_read,
        )
    }

    /// Cluster-aware decode derivation (see [`Self::decode_from_components`]
    /// for the regime discussion — this is the same formula with the
    /// projection GEMMs priced on the attention pool).
    pub fn decode_from_cluster_comps(
        model: &ModelConfig,
        comp: &ClusterComps,
        split: GroupSplit,
        kv_len: usize,
        kv_read: LinearModel,
    ) -> Self {
        Self::decode_from_cluster_comps_placed(
            model,
            comp,
            split,
            kv_len,
            kv_read,
            &ExpertPlacement::uniform(model.n_experts, split.eg),
            &ExpertLoad::uniform(model.n_experts),
        )
    }

    /// Placement-aware decode derivation (see
    /// [`Self::from_cluster_comps_placed`] for the expert-stage
    /// generalization; the attention rewrite below is placement-blind).
    pub fn decode_from_cluster_comps_placed(
        model: &ModelConfig,
        comp: &ClusterComps,
        split: GroupSplit,
        kv_len: usize,
        kv_read: LinearModel,
        placement: &ExpertPlacement,
        load: &ExpertLoad,
    ) -> Self {
        // Everything except attention — shared-expert, expert, and
        // transfer α/β plus token conservation — *is* the prefill
        // derivation at S = 1 (one token per sample), so derive it
        // there and keep one source for those formulas.
        let mut sm = Self::from_cluster_comps_placed(model, comp, split, 1, placement, load);

        let m = model.embed as f64;
        let nh = model.n_heads as f64;
        let dk = model.d_k as f64;
        let dv = model.d_v as f64;
        // Q/K/V/O projections for one token per sample (same term
        // `from_cluster_comps` derives at S = 1; recomputed rather than
        // subtracted back out of `sm.t_a.beta` so no floating-point
        // residue of the S² score term leaks in), plus the KV regime
        // replacing that score term: workload y = n_h·1·kv·(d_k+d_v)
        // vs streaming the resident KV bytes of one layer — whichever
        // bounds the kernel.
        let kv_total = kv_len as f64 + 1.0;
        let beta_gemm =
            comp.gemm_a.beta * proj_factor(model) * (2.0 * m * nh * dk + 2.0 * m * nh * dv);
        let y_decode = kv_total * nh * (dk + dv);
        let kv_bytes_layer = kv_total * model.kv_bytes_per_token_layer() as f64;
        let beta_attn = (comp.attn.beta * y_decode).max(kv_read.eval(kv_bytes_layer));
        sm.t_a = LinearModel::new(sm.t_a.alpha, beta_gemm + beta_attn);
        sm
    }

    /// Build stage models from already-fitted component models (the path
    /// used after Fig.-7-style calibration).
    pub fn from_components(
        model: &ModelConfig,
        comp: &CompModels,
        split: GroupSplit,
        seq_len: usize,
    ) -> Self {
        Self::from_cluster_comps(model, &ClusterComps::from_comp_models(comp), split, seq_len)
    }

    /// The Eqs. 10-11 derivation priced per pool: attention-side terms
    /// (projections, score kernel, shared experts) on `gemm_a`/`attn`,
    /// the expert FFN on `gemm_e`, and the transfer on the M2N `comm`
    /// model. With `gemm_a == gemm_e` (the [`ClusterComps::from_comp_models`]
    /// embedding) every expression below is the homogeneous one.
    pub fn from_cluster_comps(
        model: &ModelConfig,
        comp: &ClusterComps,
        split: GroupSplit,
        seq_len: usize,
    ) -> Self {
        Self::from_cluster_comps_placed(
            model,
            comp,
            split,
            seq_len,
            &ExpertPlacement::uniform(model.n_experts, split.eg),
            &ExpertLoad::uniform(model.n_experts),
        )
    }

    /// The Eqs. 10-11 derivation generalized over an expert placement:
    /// the expert stage is priced on the **max-loaded shard**. Two
    /// scalars replace the uniform `E/eg` factor — the busiest shard's
    /// kernel-launch count (α) and its work share
    /// `F = max_d Σ_{e∈d} rel_e/c_e` (β and the A2E payload). For the
    /// uniform placement both scalars are the literal `E/eg` division,
    /// so the legacy closed form reproduces bit for bit. `k_tokens`
    /// (global token conservation) is placement-invariant: `m_e` stays
    /// "tokens per expert per part under uniform balance" and the
    /// max-shard factor is folded into the coefficients.
    pub fn from_cluster_comps_placed(
        model: &ModelConfig,
        comp: &ClusterComps,
        split: GroupSplit,
        seq_len: usize,
        placement: &ExpertPlacement,
        load: &ExpertLoad,
    ) -> Self {
        assert_eq!(placement.n_experts(), model.n_experts, "placement/model expert mismatch");
        assert_eq!(placement.n_shards(), split.eg, "placement shards must match split.eg");
        let s = seq_len as f64;
        let m = model.embed as f64;
        let h = model.ffn_hidden as f64;
        let nh = model.n_heads as f64;
        let dk = model.d_k as f64;
        let dv = model.d_v as f64;
        let e = model.n_experts as f64;
        let nsh = model.n_shared as f64;
        let bytes = model.bytes_per_elem as f64;

        // Eq. 1 -> Eqs. 10-11; the S² attention term keeps the paper's
        // n_h·(d_k+d_v) form ("MLA can also be modeled using similar
        // formulations", §3.1).
        let alpha_a = 4.0 * comp.gemm_a.alpha + comp.attn.alpha;
        let beta_a = comp.gemm_a.beta
            * proj_factor(model)
            * (2.0 * s * m * nh * dk + 2.0 * s * m * nh * dv)
            + comp.attn.beta * s * s * nh * (dk + dv);

        // Eq. 2: t_s = 3·N_shared·t_gm(m_a·S·M·H). Shared experts are
        // replicated on the attention-group devices (§3.1), so they run
        // on the attention pool's GEMM model.
        let (alpha_s, beta_s) = if model.n_shared > 0 {
            (3.0 * nsh * comp.gemm_a.alpha, 3.0 * nsh * comp.gemm_a.beta * s * m * h)
        } else {
            (0.0, 0.0)
        };

        // Eq. 3 generalized: t_e = 3·A·α_gm + 3·F·β_gm·(m_e·M·H) on the
        // expert pool, where A = busiest shard's expert count and
        // F = max-shard work factor. Uniform placement: A = F = E/eg,
        // the paper's closed form (same division, same bits).
        let a_factor = placement.alpha_shard_experts();
        let f_load = placement.beta_shard_load(load);
        let alpha_e = 3.0 * a_factor * comp.gemm_e.alpha;
        let beta_e = 3.0 * f_load * comp.gemm_e.beta * m * h;

        // Eq. 4 generalized: the max-loaded shard receives z = F·m_e·M
        // elements -> bytes (uniform: F = E/eg).
        let alpha_a2e = comp.comm.alpha;
        let beta_a2e = comp.comm.beta * f_load * m * bytes;

        let k_tokens = split.ag as f64 * model.top_k as f64 * s / e;

        Self {
            t_a: LinearModel::new(alpha_a, beta_a),
            t_s: LinearModel::new(alpha_s, beta_s),
            t_e: LinearModel::new(alpha_e, beta_e),
            t_a2e: LinearModel::new(alpha_a2e, beta_a2e),
            k_tokens,
            has_shared: model.n_shared > 0,
        }
    }

    /// m_e for a given (m_a, r2) under token conservation
    /// `m_a·ag·top_k·S = m_e·r2·E` (§4.2, Theorem 1).
    pub fn m_e(&self, m_a: f64, r2: usize) -> f64 {
        self.k_tokens * m_a / r2 as f64
    }

    /// Stage durations at a concrete configuration.
    pub fn attn_time(&self, m_a: f64) -> f64 {
        self.t_a.eval(m_a)
    }

    pub fn shared_time(&self, m_a: f64) -> f64 {
        if self.has_shared {
            self.t_s.eval(m_a)
        } else {
            0.0
        }
    }

    pub fn expert_time(&self, m_e: f64) -> f64 {
        self.t_e.eval(m_e)
    }

    pub fn comm_time(&self, m_e: f64) -> f64 {
        self.t_a2e.eval(m_e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn models() -> StageModels {
        StageModels::new(
            &ModelConfig::deepseek_v2(8),
            &Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
        )
    }

    #[test]
    fn stage_times_positive_and_monotone() {
        let sm = models();
        assert!(sm.attn_time(1.0) > 0.0);
        assert!(sm.attn_time(4.0) > sm.attn_time(1.0));
        assert!(sm.expert_time(256.0) > sm.expert_time(16.0));
        assert!(sm.comm_time(256.0) > sm.comm_time(16.0));
        assert!(sm.shared_time(2.0) > sm.shared_time(1.0));
    }

    #[test]
    fn no_shared_expert_means_zero_shared_time() {
        let sm = StageModels::new(
            &ModelConfig::qwen3_moe(12),
            &Testbed::b(),
            GroupSplit::new(4, 4),
            2048,
        );
        assert_eq!(sm.shared_time(8.0), 0.0);
        assert!(!sm.has_shared);
    }

    #[test]
    fn token_conservation() {
        let sm = models();
        // m_a·ag·top_k·S == m_e·r2·E
        let (m_a, r2) = (4.0, 3);
        let m_e = sm.m_e(m_a, r2);
        let lhs = m_a * 3.0 * 6.0 * 2048.0;
        let rhs = m_e * r2 as f64 * 160.0;
        assert!((lhs - rhs).abs() < 1e-6 * lhs);
    }

    #[test]
    fn alpha_composition_matches_eq10() {
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let split = GroupSplit::new(3, 5);
        let comp = CompModels::from_testbed(&tb, split);
        let sm = StageModels::from_components(&model, &comp, split, 2048);
        assert!((sm.t_a.alpha - (4.0 * comp.gemm.alpha + comp.attn.alpha)).abs() < 1e-15);
        assert!((sm.t_s.alpha - 3.0 * 2.0 * comp.gemm.alpha).abs() < 1e-15);
    }

    #[test]
    fn comm_beta_scales_with_fanout() {
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let even = StageModels::new(&model, &tb, GroupSplit::new(4, 4), 2048);
        let skewed = StageModels::new(&model, &tb, GroupSplit::new(6, 2), 2048);
        // More senders than receiving bisection width => higher per-byte
        // cost per machine... but also fewer experts per EG device raises
        // (E/eg). Compare per-byte comm β directly:
        let per_byte_even = even.t_a2e.beta / (160.0 / 4.0);
        let per_byte_skewed = skewed.t_a2e.beta / (160.0 / 2.0);
        assert!(per_byte_skewed > per_byte_even);
    }

    #[test]
    fn profile_driven_comp_models_match_testbed_bitwise() {
        use crate::perfmodel::profile::CalibrationProfile;
        let tb = Testbed::c();
        let split = GroupSplit::new(4, 4);
        let hand = CompModels::from_testbed(&tb, split);
        let cal = CompModels::from_profile(&CalibrationProfile::from_testbed(&tb), &tb, split);
        assert_eq!(hand, cal, "Table-2-equivalent profile must not move a single bit");
        // ...including through the full stage derivation for both phases.
        let model = ModelConfig::qwen3_moe(12);
        let cal_tb = Testbed::from_profile(&tb, &CalibrationProfile::from_testbed(&tb));
        for phase in [Phase::Prefill, Phase::Decode { kv_len: 4096 }] {
            let a = StageModels::for_phase(&model, &tb, split, 2048, phase);
            let b = StageModels::for_phase(&model, &cal_tb, split, 2048, phase);
            assert_eq!(a, b, "{phase:?}");
        }
    }

    fn decode_models(kv: usize) -> StageModels {
        StageModels::for_phase(
            &ModelConfig::deepseek_v2(8),
            &Testbed::a(),
            GroupSplit::new(3, 5),
            2048,
            Phase::Decode { kv_len: kv },
        )
    }

    #[test]
    fn for_phase_prefill_matches_new() {
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let split = GroupSplit::new(3, 5);
        let a = StageModels::new(&model, &tb, split, 2048);
        let b = StageModels::for_phase(&model, &tb, split, 2048, Phase::Prefill);
        assert_eq!(a, b, "prefill phase must be the existing derivation, bit for bit");
    }

    #[test]
    fn decode_token_conservation_is_one_token_per_sample() {
        // m_a·ag·top_k·1 = m_e·r2·E: a decode step feeds each expert
        // well under one token per sample.
        let sm = decode_models(2048);
        assert!((sm.k_tokens - 3.0 * 6.0 / 160.0).abs() < 1e-15);
        assert!(sm.m_e(4.0, 1) < 1.0, "m_e = {}", sm.m_e(4.0, 1));
    }

    #[test]
    fn decode_attention_is_kv_read_bound_and_grows_with_kv() {
        let model = ModelConfig::deepseek_v2(8);
        let tb = Testbed::a();
        let split = GroupSplit::new(3, 5);
        let comp = CompModels::from_testbed(&tb, split);
        let sm = decode_models(2048);
        // The KV-read term dominates the score FLOPs by orders of
        // magnitude on every paper testbed: subtracting the projection
        // GEMM part leaves exactly bytes / hbm_bw.
        let beta_gemm =
            comp.gemm.beta * 0.35 * (2.0 * 5120.0 * 128.0 * 192.0 + 2.0 * 5120.0 * 128.0 * 128.0);
        let kv_bytes = 2049.0 * model.kv_bytes_per_token_layer() as f64;
        let expect_mem = kv_bytes / tb.hbm_bw;
        assert!((sm.t_a.beta - beta_gemm - expect_mem).abs() < 1e-12 * expect_mem);
        // …and it genuinely is the binding term (the max picked it over
        // the score FLOPs; MLA's compressed latent keeps the ratio
        // modest, MHA models are memory-bound by orders of magnitude).
        assert!(expect_mem > comp.attn.beta * 2049.0 * 128.0 * 320.0, "not memory-bound");
        // Longer KV costs more attention; expert/comm coefficients are
        // KV-independent.
        let long = decode_models(8192);
        assert!(long.attn_time(1.0) > sm.attn_time(1.0));
        assert_eq!(long.t_e, sm.t_e);
        assert_eq!(long.t_a2e, sm.t_a2e);
    }

    #[test]
    fn decode_shared_expert_runs_on_one_token() {
        let pre = models(); // S = 2048
        let dec = decode_models(2048);
        // Shared-expert β shrinks by exactly the S factor.
        assert!((pre.t_s.beta / dec.t_s.beta - 2048.0).abs() < 1e-9 * 2048.0);
        assert_eq!(pre.t_s.alpha, dec.t_s.alpha);
    }

    #[test]
    fn for_cluster_single_pool_matches_for_phase_bitwise() {
        use crate::config::Cluster;
        for tb in Testbed::all() {
            let cl = Cluster::single_pool(&tb);
            for model in [ModelConfig::deepseek_v2(8), ModelConfig::qwen3_moe(12)] {
                let split = GroupSplit::paper_default(&tb, model.n_shared > 0);
                for phase in [Phase::Prefill, Phase::Decode { kv_len: 2048 }] {
                    let a = StageModels::for_phase(&model, &tb, split, 2048, phase);
                    let b = StageModels::for_cluster(&model, &cl, split, 2048, phase);
                    assert_eq!(a, b, "{} {phase:?}", tb.name);
                }
            }
        }
    }

    #[test]
    fn hetero_cluster_routes_coefficients_per_pool() {
        use crate::config::Cluster;
        let model = ModelConfig::deepseek_v2(8);
        let split = GroupSplit::new(3, 5);
        let cl = Cluster::reference_hetero();
        let comp = ClusterComps::from_cluster(&cl, split);
        // Distinct pool silicon => distinct GEMM models.
        assert_ne!(comp.gemm_a, comp.gemm_e);
        let sm = StageModels::for_cluster(&model, &cl, split, 2048, Phase::Prefill);
        // t_a and t_s price on the attention pool, t_e on the expert
        // pool: verify against single-pool derivations of each spec.
        let mut attn_only = cl.clone();
        attn_only.pools[1].gpu = attn_only.pools[0].gpu.clone();
        let on_attn = StageModels::for_cluster(&model, &attn_only, split, 2048, Phase::Prefill);
        assert_eq!(sm.t_a, on_attn.t_a);
        assert_eq!(sm.t_s, on_attn.t_s);
        assert_ne!(sm.t_e, on_attn.t_e, "expert FFN must price on the expert pool");
        let mut expert_only = cl.clone();
        expert_only.pools[0].gpu = expert_only.pools[1].gpu.clone();
        let on_expert = StageModels::for_cluster(&model, &expert_only, split, 2048, Phase::Prefill);
        assert_eq!(sm.t_e, on_expert.t_e);
        assert_ne!(sm.t_a, on_expert.t_a, "attention must price on the attention pool");
        // Decode KV streaming binds at the attention pool's HBM.
        let dec =
            StageModels::for_cluster(&model, &cl, split, 2048, Phase::Decode { kv_len: 4096 });
        let dec_slow_hbm = {
            let mut c = cl.clone();
            c.pools[0].gpu.hbm_bw /= 8.0;
            StageModels::for_cluster(&model, &c, split, 2048, Phase::Decode { kv_len: 4096 })
        };
        assert!(dec_slow_hbm.t_a.beta > dec.t_a.beta);
        let dec_slow_expert_hbm = {
            let mut c = cl.clone();
            c.pools[1].gpu.hbm_bw /= 8.0;
            StageModels::for_cluster(&model, &c, split, 2048, Phase::Decode { kv_len: 4096 })
        };
        assert_eq!(
            dec_slow_expert_hbm.t_a,
            dec.t_a,
            "expert-pool HBM must not touch decode attention"
        );
    }

    #[test]
    fn longer_sequences_cost_more_attention() {
        let model = ModelConfig::qwen3_moe(12);
        let tb = Testbed::c();
        let split = GroupSplit::new(4, 4);
        let short = StageModels::new(&model, &tb, split, 1024);
        let long = StageModels::new(&model, &tb, split, 8192);
        // Attention grows superlinearly in S (S² term), per-token compute grows.
        assert!(long.attn_time(1.0) > 8.0 * short.attn_time(1.0));
    }
}
