//! Resilience tier: the replica health state machine, the fault plane,
//! and the exactly-once delivery protocol under seeded random fault
//! schedules.
//!
//! Nothing here touches the AOT artifacts — the pool is exercised with
//! unit replicas and the delivery protocol with simulated serves — so
//! this tier runs everywhere the library builds, single- or
//! multi-threaded (`RUST_TEST_THREADS=1` and `=8` in CI). Every random
//! schedule is seeded, so a failure reproduces from its printed seed.

use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use findep::coordinator::batcher::{run_attempt, FailedRequest, RequestError};
use findep::coordinator::executor::{run_worker, EventCore};
use findep::coordinator::faults::{FaultAction, FaultPlan};
use findep::coordinator::planner::PlannerConfig;
use findep::coordinator::server::{
    EmbeddedRequest, HealthConfig, HealthState, ReplicaPool, Response,
};
use findep::metrics::Registry;
use findep::util::rng::Rng;

// ---- health state machine (unit replicas, no serving) ------------------

fn health_cfg(cooldown_ms: u64) -> HealthConfig {
    HealthConfig {
        degrade_after: 1,
        quarantine_after: 3,
        outlier_factor: 4.0,
        outlier_after: 2,
        cooldown: Duration::from_millis(cooldown_ms),
        probation_successes: 2,
    }
}

/// Report one outcome through a fresh lease on the pool's only replica.
fn report_once(pool: &ReplicaPool<()>, ok: bool, latency_s: f64) {
    let lease = pool.try_lease().expect("sole replica must be leasable");
    lease.report(ok, latency_s);
}

#[test]
fn errors_degrade_then_quarantine_then_probation_recovers() {
    let pool = ReplicaPool::new(vec![()]).with_health(health_cfg(20));
    assert_eq!(pool.health_state(0), HealthState::Healthy);

    // One error: Healthy -> Degraded, but the replica keeps serving.
    report_once(&pool, false, 0.0);
    assert_eq!(pool.health_state(0), HealthState::Degraded);
    assert_eq!(pool.available(), 1, "degraded replicas still serve");

    // One clean serve clears an error-degraded replica.
    report_once(&pool, true, 0.001);
    assert_eq!(pool.health_state(0), HealthState::Healthy);

    // Three consecutive errors: -> Quarantined, off the free list.
    for _ in 0..3 {
        report_once(&pool, false, 0.0);
    }
    assert_eq!(pool.health_state(0), HealthState::Quarantined);
    assert_eq!(pool.available(), 0);
    assert_eq!(pool.quarantined(), 1);
    assert!(pool.try_lease().is_none(), "quarantined replicas must not lease");

    // After the cooldown it re-admits on probation (Degraded), and
    // `probation_successes` clean serves restore Healthy.
    std::thread::sleep(Duration::from_millis(40));
    {
        let lease = pool.try_lease().expect("cooldown elapsed: replica re-admitted");
        assert_eq!(pool.health_state(0), HealthState::Degraded);
        lease.report(true, 0.001);
    }
    assert_eq!(pool.health_state(0), HealthState::Degraded, "probation needs 2 successes");
    report_once(&pool, true, 0.001);
    assert_eq!(pool.health_state(0), HealthState::Healthy);
}

#[test]
fn probation_error_requarantines_immediately() {
    let pool = ReplicaPool::new(vec![()]).with_health(health_cfg(10));
    for _ in 0..3 {
        report_once(&pool, false, 0.0);
    }
    assert_eq!(pool.health_state(0), HealthState::Quarantined);
    std::thread::sleep(Duration::from_millis(25));
    // First error while on probation: no second benefit of the doubt.
    report_once(&pool, false, 0.0);
    assert_eq!(pool.health_state(0), HealthState::Quarantined);
    assert_eq!(pool.quarantined(), 1);
}

#[test]
fn latency_outliers_degrade_against_the_pool_ewma() {
    let pool = ReplicaPool::new(vec![()]).with_health(HealthConfig {
        // Outlier-only path: errors alone never degrade here.
        degrade_after: 100,
        quarantine_after: 100,
        ..health_cfg(10)
    });
    // Warm the pool-wide EWMA past its 8-sample outlier warmup.
    for _ in 0..8 {
        report_once(&pool, true, 0.010);
    }
    assert_eq!(pool.health_state(0), HealthState::Healthy);
    // Two consecutive 10x serves (outlier_factor is 4x): -> Degraded.
    report_once(&pool, true, 0.100);
    report_once(&pool, true, 0.100);
    assert_eq!(pool.health_state(0), HealthState::Degraded);
    // A normal-latency serve clears it.
    report_once(&pool, true, 0.010);
    assert_eq!(pool.health_state(0), HealthState::Healthy);
}

#[test]
fn blocking_lease_survives_a_fully_quarantined_pool() {
    // The sole replica quarantines; a blocking lease() must park with a
    // cooldown-bounded timeout and self-recover, not deadlock.
    let pool = ReplicaPool::new(vec![()]).with_health(health_cfg(50));
    for _ in 0..3 {
        report_once(&pool, false, 0.0);
    }
    assert_eq!(pool.available(), 0);
    let t0 = Instant::now();
    let lease = pool.lease();
    let waited = t0.elapsed();
    assert_eq!(lease.replica_id(), 0);
    assert!(waited >= Duration::from_millis(40), "lease returned before cooldown: {waited:?}");
    assert!(waited < Duration::from_secs(10), "lease took implausibly long: {waited:?}");
    assert_eq!(pool.health_state(0), HealthState::Degraded, "re-admitted on probation");
}

#[test]
fn fault_plan_fires_at_the_lease_boundary_per_replica_ordinal() {
    let metrics = Arc::new(Registry::new());
    let plan = FaultPlan::parse("0=fail:2", 2).unwrap();
    let pool = ReplicaPool::new(vec![(), ()]).with_faults(plan).with_metrics(metrics.clone());
    // Pop order is back-first: hold replica 1 so the next lease is 0.
    let healthy = pool.try_lease().unwrap();
    assert_eq!(healthy.replica_id(), 1);
    let faulty = pool.try_lease().unwrap();
    assert_eq!(faulty.replica_id(), 0);
    assert_eq!(faulty.fault_action(), FaultAction::Fail);
    assert_eq!(faulty.fault_action(), FaultAction::Fail);
    assert_eq!(faulty.fault_action(), FaultAction::None, "transient recovers after 2");
    assert_eq!(healthy.fault_action(), FaultAction::None, "other replica untouched");
    assert_eq!(metrics.counter("faults_injected"), 2);
}

#[test]
fn disarmed_fault_plane_touches_no_counters() {
    let metrics = Arc::new(Registry::new());
    let pool = ReplicaPool::new(vec![()]).with_metrics(metrics.clone());
    let lease = pool.try_lease().unwrap();
    for _ in 0..10 {
        assert_eq!(lease.fault_action(), FaultAction::None);
    }
    assert_eq!(metrics.counter("faults_injected"), 0);
}

// ---- exactly-once delivery under faults (simulated serves) -------------

/// A full simulated serving stack: the real event core, worker loop,
/// and `run_attempt` protocol, with `Server::serve_batch` replaced by
/// an echo over a fault-injecting unit-replica pool.
struct SimStack {
    core: Arc<EventCore>,
    metrics: Arc<Registry>,
    resp_rx: std::sync::mpsc::Receiver<Response>,
    fail_rx: std::sync::mpsc::Receiver<FailedRequest>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

fn sim_stack(workers: usize, max_batch: usize, max_retries: u32, plan: FaultPlan) -> SimStack {
    let core = Arc::new(EventCore::new(PlannerConfig {
        max_batch,
        linger: Duration::from_micros(200),
        queue_depth: 16,
    }));
    let metrics = Arc::new(Registry::new());
    let pool = Arc::new(
        ReplicaPool::new(vec![(); workers])
            .with_health(health_cfg(2))
            .with_faults(plan)
            .with_metrics(metrics.clone()),
    );
    let (resp_tx, resp_rx) = channel::<Response>();
    let (fail_tx, fail_rx) = channel::<FailedRequest>();
    let mut threads = Vec::new();
    for _ in 0..workers {
        core.register_worker();
        let core = core.clone();
        let metrics = metrics.clone();
        let pool = pool.clone();
        let resp_tx = resp_tx.clone();
        let fail_tx = fail_tx.clone();
        threads.push(std::thread::spawn(move || {
            let c = core.clone();
            let m = metrics.clone();
            run_worker(&core, &metrics, move |batch| {
                run_attempt(&c, &m, &resp_tx, &fail_tx, max_retries, 2, batch, |reqs| {
                    let lease = pool.lease();
                    match lease.fault_action() {
                        FaultAction::Fail => {
                            lease.report(false, 0.0);
                            Err(anyhow::anyhow!("injected fault"))
                        }
                        FaultAction::Panic => {
                            lease.report(false, 0.0);
                            panic!("injected worker panic")
                        }
                        FaultAction::Slow(factor) => {
                            std::thread::sleep(Duration::from_micros((40.0 * factor) as u64));
                            lease.report(true, 0.001);
                            Ok(echo(reqs))
                        }
                        FaultAction::None => {
                            lease.report(true, 0.001);
                            Ok(echo(reqs))
                        }
                    }
                })
            });
        }));
    }
    SimStack { core, metrics, resp_rx, fail_rx, threads }
}

fn echo(reqs: &[EmbeddedRequest]) -> Vec<Response> {
    reqs.iter()
        .map(|r| Response { id: r.id, hidden: r.hidden.clone(), latency_s: 0.0 })
        .collect()
}

impl SimStack {
    /// Collect `n` terminal outcomes, then close and join.
    fn finish(self, n: usize) -> (Vec<Response>, Vec<FailedRequest>) {
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut resps = Vec::new();
        let mut fails = Vec::new();
        while resps.len() + fails.len() < n && Instant::now() < deadline {
            if let Ok(r) = self.resp_rx.try_recv() {
                resps.push(r);
                continue;
            }
            if let Ok(f) = self.fail_rx.try_recv() {
                fails.push(f);
                continue;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
        assert_eq!(
            resps.len() + fails.len(),
            n,
            "stack timed out with {} responses + {} failures of {n}",
            resps.len(),
            fails.len(),
        );
        assert_eq!(self.core.open(), 0, "terminal outcomes must release every open slot");
        self.core.close();
        for t in self.threads {
            t.join().unwrap();
        }
        (resps, fails)
    }
}

#[test]
fn every_request_terminates_exactly_once_under_random_fault_schedules() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1));
        let workers = 2 + rng.usize_below(3); // 2..=4 replicas
        let max_batch = 1 + rng.usize_below(4);
        let max_retries = 1 + rng.below(3) as u32;
        let n = 24u64;
        let plan = FaultPlan::random(seed, workers);
        let stack = sim_stack(workers, max_batch, max_retries, plan);
        for i in 0..n {
            let out_len = rng.usize_below(3); // mix prefill-only and decode
            stack.core.submit(EmbeddedRequest::synthetic_autoregressive(i, 2, 2, out_len)).unwrap();
        }
        let (resps, fails) = stack.finish(n as usize);

        // Exactly once: every submitted id appears exactly once across
        // the response and failure channels — none lost, none repeated.
        let mut ids: Vec<u64> =
            resps.iter().map(|r| r.id).chain(fails.iter().map(|f| f.id)).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "seed {seed}: lost or duplicated ids");
        for f in &fails {
            assert!(
                matches!(f.error, RequestError::RetriesExhausted { attempts } if attempts > 0),
                "seed {seed}: unexpected failure kind {:?}",
                f.error
            );
        }
    }
}

#[test]
fn fault_free_single_worker_stack_is_fifo_and_inert() {
    let stack = sim_stack(1, 4, 2, FaultPlan::default());
    for i in 0..12u64 {
        stack.core.submit(EmbeddedRequest::synthetic(i, 2, 2)).unwrap();
    }
    let (resps, fails) = stack.finish(12);
    assert!(fails.is_empty());
    let ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..12).collect::<Vec<_>>(), "single worker must drain FIFO");
    // The fault plane and retry machinery leave no trace on a clean run.
    let m = &stack.metrics;
    for c in [
        "faults_injected",
        "request_retries",
        "requests_failed",
        "requests_expired",
        "serve_errors",
    ] {
        assert_eq!(m.counter(c), 0, "counter {c} moved on a fault-free run");
    }
}

#[test]
fn serving_records_ttft_and_tpot_for_slo_grading() {
    use findep::coordinator::slo::SloPolicy;
    let stack = sim_stack(1, 4, 2, FaultPlan::default());
    // Six autoregressive requests, two output tokens each: one prefill
    // pass (the first token — TTFT) plus two decode passes (one TPOT
    // sample per generated token).
    for i in 0..6u64 {
        stack.core.submit(EmbeddedRequest::synthetic_autoregressive(i, 2, 2, 2)).unwrap();
    }
    let (resps, fails) = stack.finish(6);
    assert!(fails.is_empty());
    assert_eq!(resps.len(), 6);
    let m = &stack.metrics;
    assert_eq!(m.histogram_count("ttft"), 6, "one TTFT sample per request");
    assert_eq!(m.histogram_count("tpot"), 12, "one TPOT sample per decode pass");
    // The recorded distributions are exactly what an SLO policy grades.
    let loose = SloPolicy::new(Some(3600.0), Some(3600.0), 99.0).evaluate(m);
    assert_eq!(loose.ttft_met, Some(true));
    assert_eq!(loose.tpot_met, Some(true));
    assert!(loose.met());
    assert_eq!(loose.attainment(m), 1.0, "every sample under an hour-long target");
    let tight = SloPolicy::new(Some(0.0), None, 50.0).evaluate(m);
    assert_eq!(tight.ttft_met, Some(false), "a zero-latency target cannot hold");
    assert!(!tight.met());
}

#[test]
fn expired_requests_fail_fast_without_touching_a_replica() {
    // Serve closure panics if ever invoked: an expired request must be
    // failed at assembly, before any replica lease.
    let core = Arc::new(EventCore::new(PlannerConfig {
        max_batch: 4,
        linger: Duration::from_micros(100),
        queue_depth: 16,
    }));
    let metrics = Arc::new(Registry::new());
    let (resp_tx, resp_rx) = channel::<Response>();
    let (fail_tx, fail_rx) = channel::<FailedRequest>();
    core.register_worker();
    let t = {
        let core2 = core.clone();
        let metrics2 = metrics.clone();
        std::thread::spawn(move || {
            let c = core2.clone();
            let m = metrics2.clone();
            run_worker(&core2, &metrics2, move |batch| {
                run_attempt(&c, &m, &resp_tx, &fail_tx, 2, 2, batch, |_reqs| {
                    panic!("expired batch reached the serve path")
                })
            });
        })
    };
    let past = Instant::now() - Duration::from_millis(5);
    for i in 0..4u64 {
        core.submit(EmbeddedRequest::synthetic(i, 2, 2).with_deadline(past)).unwrap();
    }
    let mut fails = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(10);
    while fails.len() < 4 && Instant::now() < deadline {
        if let Ok(f) = fail_rx.try_recv() {
            fails.push(f);
        } else {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    assert_eq!(fails.len(), 4, "every expired request must fail fast");
    assert!(fails.iter().all(|f| f.error == RequestError::DeadlineExpired));
    assert!(resp_rx.try_recv().is_err(), "no responses for expired requests");
    assert_eq!(core.open(), 0);
    assert_eq!(metrics.counter("requests_expired"), 4);
    core.close();
    t.join().unwrap();
}

#[test]
fn permanent_fault_on_the_sole_replica_exhausts_the_retry_budget() {
    let stack = sim_stack(1, 4, 2, FaultPlan::parse("0=perm", 1).unwrap());
    stack.core.submit(EmbeddedRequest::synthetic(7, 2, 2)).unwrap();
    let (resps, fails) = stack.finish(1);
    assert!(resps.is_empty());
    assert_eq!(fails.len(), 1);
    assert_eq!(fails[0].id, 7);
    assert_eq!(fails[0].error, RequestError::RetriesExhausted { attempts: 3 });
    assert_eq!(stack.metrics.counter("request_retries"), 2);
    assert_eq!(stack.metrics.counter("requests_failed"), 1);
}

#[test]
fn injected_worker_panic_retries_the_batch_on_a_survivor() {
    // Replica 1 — the one the pool leases first (pop from the back) —
    // panics its worker on its first serve; the drop guard must route
    // the batch to the retry lane and the surviving worker completes
    // it. (The panicking thread dies — join reports Err — but no
    // request is lost.)
    let stack = sim_stack(2, 4, 2, FaultPlan::parse("1=panic@0", 2).unwrap());
    for i in 0..8u64 {
        stack.core.submit(EmbeddedRequest::synthetic(i, 2, 2)).unwrap();
    }
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut resps = Vec::new();
    while resps.len() < 8 && Instant::now() < deadline {
        if let Ok(r) = stack.resp_rx.try_recv() {
            resps.push(r);
        } else {
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..8).collect::<Vec<_>>(), "panic lost or duplicated requests");
    assert!(stack.fail_rx.try_recv().is_err(), "retry must absorb the panic, not fail");
    assert_eq!(stack.core.open(), 0);
    stack.core.close();
    let mut panicked = 0;
    for t in stack.threads {
        if t.join().is_err() {
            panicked += 1;
        }
    }
    assert_eq!(panicked, 1, "exactly the injected panic");
    assert!(stack.metrics.counter("request_retries") >= 1);
}
