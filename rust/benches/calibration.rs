//! Calibration-profile gates: fit → persist → solve must be lossless.
//!
//! Two hard gates, asserted before any timing:
//!
//! 1. **Bit-identity** — a synthetic profile whose constants equal
//!    Table-2's, routed through its *serialized JSON form* (exactly the
//!    `calibrate --out` → `solve --profile` workflow), produces
//!    bit-identical plans, makespans, and throughputs to the
//!    hand-constant path on every paper instance, prefill and decode.
//! 2. **No cross-profile aliasing** — a perturbed profile produces a
//!    *different* cached plan: the plan cache keyed by profile
//!    fingerprint holds both entries, each hit returns its own plan,
//!    and the hand-constant keyspace stays untouched.
//!
//! Also times the profile-driven solve against the hand-constant solve
//! (the indirection must be free — both paths run the same Testbed
//! derivation) and the JSON round-trip itself.
//!
//! Emits a `BENCH_calibration.json` trajectory file.
//!
//! Run: `cargo bench --bench calibration`

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::perfmodel::{CalibrationProfile, ProfileThresholds};
use findep::solver::{self, Instance, PlanCache, ShapeKey, SolverParams};
use findep::util::bench::{fmt_duration, Bencher, Table};
use findep::util::json::{parse, to_string_pretty, Json, JsonObj};

fn paper_cases() -> Vec<(String, ModelConfig, Testbed, GroupSplit)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for (deepseek, name) in [(true, "deepseek"), (false, "qwen")] {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            let split = GroupSplit::paper_default(&tb, model.has_shared_expert());
            out.push((format!("{name}/{}", tb.name), model, tb.clone(), split));
        }
    }
    out
}

/// Route a profile through its serialized form, as the CLI would.
fn round_trip(prof: &CalibrationProfile) -> CalibrationProfile {
    let text = to_string_pretty(&prof.to_json());
    CalibrationProfile::from_json(&parse(&text).expect("profile JSON parses"))
        .expect("profile JSON loads")
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let params = SolverParams::default();
    let seq = 2048usize;

    let mut report = JsonObj::new();
    report.insert("bench", Json::Str("calibration".into()));
    report.insert("quick", Json::Bool(quick));

    // ---- Gate 1: Table-2-equivalent profile is bit-identical. --------
    let mut table = Table::new(
        "Profile-driven solve vs hand constants (Table-2-equivalent profile)",
        &["instance", "phase", "tokens/s", "bit-identical", "hand solve", "profile solve"],
    );
    let mut entries: Vec<Json> = Vec::new();
    let (mut sum_hand, mut sum_prof) = (0.0f64, 0.0f64);
    for (label, model, tb, split) in paper_cases() {
        let prof = round_trip(&CalibrationProfile::from_testbed(&tb));
        prof.validate(&ProfileThresholds::default()).expect("synthetic profile valid");
        let cal_tb = Testbed::from_profile(&tb, &prof);
        for (phase_name, inst, cal_inst) in [
            (
                "prefill",
                Instance::new(model.clone(), tb.clone(), split, seq),
                Instance::new(model.clone(), cal_tb.clone(), split, seq),
            ),
            (
                "decode",
                Instance::decode(model.clone(), tb.clone(), split, seq),
                Instance::decode(model.clone(), cal_tb.clone(), split, seq),
            ),
        ] {
            let hand = solver::solve(&inst, &params);
            let cal = solver::solve(&cal_inst, &params);
            let (hand, cal) = match (hand, cal) {
                (Some(h), Some(c)) => (h, c),
                (None, None) => continue,
                (h, c) => panic!(
                    "feasibility disagreement on {label}/{phase_name}: hand={} profile={}",
                    h.is_some(),
                    c.is_some()
                ),
            };
            assert_eq!(hand.config, cal.config, "plan differs on {label}/{phase_name}");
            assert_eq!(
                hand.throughput_tokens.to_bits(),
                cal.throughput_tokens.to_bits(),
                "throughput differs on {label}/{phase_name}"
            );
            assert_eq!(
                hand.makespan.to_bits(),
                cal.makespan.to_bits(),
                "makespan differs on {label}/{phase_name}"
            );

            let r_hand = bencher.run(&format!("{label}/{phase_name}/hand"), || {
                let _ = solver::solve(&inst, &params);
            });
            let r_prof = bencher.run(&format!("{label}/{phase_name}/profile"), || {
                let _ = solver::solve(&cal_inst, &params);
            });
            sum_hand += r_hand.mean_s();
            sum_prof += r_prof.mean_s();
            table.row(&[
                label.clone(),
                phase_name.to_string(),
                format!("{:.0}", hand.throughput_tokens),
                "yes".into(),
                fmt_duration(r_hand.mean_s()),
                fmt_duration(r_prof.mean_s()),
            ]);
            let mut e = JsonObj::new();
            e.insert("instance", Json::Str(label.clone()));
            e.insert("phase", Json::Str(phase_name.into()));
            e.insert("config", Json::Str(hand.config.describe()));
            e.insert("tokens_per_s", Json::Num(hand.throughput_tokens));
            e.insert("bit_identical", Json::Bool(true));
            e.insert("hand_solve_mean_s", Json::Num(r_hand.mean_s()));
            e.insert("profile_solve_mean_s", Json::Num(r_prof.mean_s()));
            entries.push(Json::Obj(e));
        }
    }
    table.print();
    println!(
        "aggregate solve time: hand {} vs profile-driven {} (same derivation, must be ~free)",
        fmt_duration(sum_hand),
        fmt_duration(sum_prof)
    );
    report.insert("instances", Json::Arr(entries));
    report.insert("aggregate_hand_solve_s", Json::Num(sum_hand));
    report.insert("aggregate_profile_solve_s", Json::Num(sum_prof));

    // ---- Gate 2: perturbed profile → different plan, no aliasing. ----
    let model = ModelConfig::deepseek_v2(8);
    let tb = Testbed::a();
    let split = GroupSplit::new(3, 5);
    let table2 = round_trip(&CalibrationProfile::from_testbed(&tb));
    // Strictly slower GEMM + link: every candidate's makespan strictly
    // grows, so the winning throughput must strictly drop — "different
    // plan" is guaranteed by monotonicity, not by luck.
    let mut perturbed = CalibrationProfile::from_testbed(&tb);
    perturbed.gemm.unit_per_s *= 0.5;
    perturbed.comm.unit_per_s *= 0.5;
    let perturbed = round_trip(&perturbed);
    assert_ne!(table2.fingerprint(), perturbed.fingerprint(), "fingerprints must separate");

    let cache = PlanCache::new();
    let batch = 8usize;
    let solve_under = |prof: &CalibrationProfile| {
        let inst = Instance::new(model.clone(), Testbed::from_profile(&tb, prof), split, seq);
        cache
            .get_or_solve(ShapeKey::prefill(seq, batch).with_profile(prof.fingerprint()), || {
                solver::solve_online(&inst, batch, &params)
            })
            .expect("paper instance is feasible")
    };
    let base = solve_under(&table2);
    let moved = solve_under(&perturbed);
    assert_eq!(cache.misses(), 2, "each profile must solve its own entry (no aliasing)");
    assert_eq!(cache.hits(), 0);
    assert_eq!(cache.len(), 2);
    assert_ne!(
        base.throughput_tokens.to_bits(),
        moved.throughput_tokens.to_bits(),
        "perturbed constants must move the cached plan"
    );
    let base2 = solve_under(&table2);
    let moved2 = solve_under(&perturbed);
    assert_eq!(cache.hits(), 2, "re-queries hit their own keyspaces");
    assert_eq!(base.config, base2.config);
    assert_eq!(moved.config, moved2.config);
    println!(
        "cross-profile isolation: {} entries, perturbed plan {} vs base {} tokens/s",
        cache.len(),
        moved.throughput_tokens,
        base.throughput_tokens
    );
    let mut iso = JsonObj::new();
    iso.insert("cache_entries", Json::Num(cache.len() as f64));
    iso.insert("base_tokens_per_s", Json::Num(base.throughput_tokens));
    iso.insert("perturbed_tokens_per_s", Json::Num(moved.throughput_tokens));
    iso.insert("base_config", Json::Str(base.config.describe()));
    iso.insert("perturbed_config", Json::Str(moved.config.describe()));
    report.insert("isolation", Json::Obj(iso));

    // ---- Round-trip cost (serialize + parse + validate). -------------
    let prof = CalibrationProfile::from_testbed(&tb);
    let r_rt = bencher.run("profile/json_round_trip", || {
        let back = round_trip(&prof);
        assert_eq!(back.fingerprint(), prof.fingerprint());
    });
    println!("profile JSON round-trip: {}", r_rt.report());
    report.insert("round_trip_mean_s", Json::Num(r_rt.mean_s()));

    std::fs::write("BENCH_calibration.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_calibration.json");
    println!("wrote BENCH_calibration.json");
}
