//! Cluster / testbed configurations (Table 2) and the DEP group split.
//!
//! The paper's four testbeds span three hardware regimes: compute-bound
//! with modest interconnect (A: 8×A6000 NVLink-bridged), comm-bound
//! (B: 8×A10, PCIe only), comm-cheap (C: 8×H20, fat NVLink), and
//! multi-node balanced (D: 32×H20, NVLink intra-node + network across
//! nodes). We reproduce those *regimes* with effective-throughput
//! constants; absolute numbers differ from the authors' testbeds, the
//! relative behaviours (who is bottlenecked on what) are what Tables 3-7
//! exercise.

use crate::util::json::{Json, JsonObj};

/// One hardware testbed.
#[derive(Debug, Clone, PartialEq)]
pub struct Testbed {
    pub name: String,
    pub n_gpus: usize,
    /// Device memory per GPU, bytes.
    pub mem_bytes: usize,
    /// Achieved dense-GEMM throughput used for β_gm, FLOP/s.
    pub gemm_flops: f64,
    /// Achieved attention throughput used for β_attn, FLOP/s (attention
    /// is less MXU-friendly than plain GEMM; the paper fits it separately
    /// in Fig. 7a).
    pub attn_flops: f64,
    /// Kernel-launch / dispatch fixed overhead, seconds (α_gm).
    pub alpha_comp_s: f64,
    /// Attention-kernel fixed overhead, seconds (α_attn).
    pub alpha_attn_s: f64,
    /// Per-GPU inter-group link bandwidth, bytes/s.
    pub link_bw: f64,
    /// Network/transfer startup latency, seconds (α_c).
    pub alpha_comm_s: f64,
    /// Achieved device-memory (HBM) streaming bandwidth, bytes/s — the
    /// decode-phase attention regime is bound by KV-cache reads at this
    /// rate rather than by attention FLOPs.
    pub hbm_bw: f64,
    pub nvlink: bool,
    pub multi_node: bool,
}

impl Testbed {
    /// Testbed A — 8× RTX A6000 48 GB, NVLink bridges, PCIe 4.0 host.
    pub fn a() -> Self {
        Self {
            name: "A (8xA6000)".into(),
            n_gpus: 8,
            mem_bytes: 48 * GB,
            gemm_flops: 110e12,
            attn_flops: 80e12,
            alpha_comp_s: 18e-6,
            alpha_attn_s: 25e-6,
            // NVLink bridges only pair GPUs; cross-group NCCL rides the
            // PCIe-4 fabric with contention.
            link_bw: 12e9,
            alpha_comm_s: 30e-6,
            hbm_bw: 768e9, // GDDR6 A6000
            nvlink: true,
            multi_node: false,
        }
    }

    /// Testbed B — 8× A10 24 GB, PCIe 4.0 x16 only (comm-bound regime).
    pub fn b() -> Self {
        Self {
            name: "B (8xA10)".into(),
            n_gpus: 8,
            mem_bytes: 24 * GB,
            gemm_flops: 90e12,
            attn_flops: 60e12,
            alpha_comp_s: 18e-6,
            alpha_attn_s: 25e-6,
            link_bw: 8e9, // PCIe 4.0 shared fabric, no NVLink (comm-bound)
            alpha_comm_s: 40e-6,
            hbm_bw: 600e9, // GDDR6 A10
            nvlink: false,
            multi_node: false,
        }
    }

    /// Testbed C — 8× H20 96 GB, 900 GB/s NVLink (comm-cheap regime).
    pub fn c() -> Self {
        Self {
            name: "C (8xH20)".into(),
            n_gpus: 8,
            mem_bytes: 96 * GB,
            gemm_flops: 130e12,
            attn_flops: 100e12,
            alpha_comp_s: 12e-6,
            alpha_attn_s: 18e-6,
            link_bw: 300e9, // NVSwitch effective per-GPU (comm-cheap)
            alpha_comm_s: 20e-6,
            hbm_bw: 4000e9, // HBM3 H20
            nvlink: true,
            multi_node: false,
        }
    }

    /// Testbed D — 4 nodes × 8 H20 (32 GPUs); inter-group traffic crosses
    /// the node network, so bandwidth sits between B and C (balanced
    /// regime, §5.5 Discussion).
    pub fn d() -> Self {
        Self {
            name: "D (32xH20)".into(),
            n_gpus: 32,
            mem_bytes: 96 * GB,
            gemm_flops: 130e12,
            attn_flops: 100e12,
            alpha_comp_s: 12e-6,
            alpha_attn_s: 18e-6,
            link_bw: 35e9, // 400G-class NICs across nodes (balanced)
            alpha_comm_s: 80e-6,
            hbm_bw: 4000e9, // HBM3 H20
            nvlink: true,
            multi_node: true,
        }
    }

    /// Replace the measured component constants with a calibration
    /// profile's fitted values, keeping `base`'s cluster topology (GPU
    /// count, device memory, link kind, node layout): host probes can
    /// measure throughputs and launch overheads, not how many GPUs the
    /// deployment has. This is the trace-driven counterpart of the
    /// hand-written Table-2 constructors — everything downstream
    /// (stage models, memory model, solver, simulator) is untouched,
    /// so a profile whose constants equal Table-2's reproduces the
    /// hand-constant solve bit for bit.
    pub fn from_profile(
        base: &Testbed,
        profile: &crate::perfmodel::profile::CalibrationProfile,
    ) -> Self {
        Self {
            name: format!("{} [calibrated: {}]", base.name, profile.host),
            gemm_flops: profile.gemm.unit_per_s,
            alpha_comp_s: profile.gemm.alpha_s,
            attn_flops: profile.attn.unit_per_s,
            alpha_attn_s: profile.attn.alpha_s,
            link_bw: profile.comm.unit_per_s,
            alpha_comm_s: profile.comm.alpha_s,
            hbm_bw: profile.hbm.unit_per_s,
            ..base.clone()
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_uppercase().as_str() {
            "A" => Some(Self::a()),
            "B" => Some(Self::b()),
            "C" => Some(Self::c()),
            "D" => Some(Self::d()),
            _ => None,
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::a(), Self::b(), Self::c(), Self::d()]
    }

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", Json::Str(self.name.clone()));
        o.insert("n_gpus", Json::Num(self.n_gpus as f64));
        o.insert("mem_bytes", Json::Num(self.mem_bytes as f64));
        o.insert("gemm_flops", Json::Num(self.gemm_flops));
        o.insert("attn_flops", Json::Num(self.attn_flops));
        o.insert("alpha_comp_s", Json::Num(self.alpha_comp_s));
        o.insert("alpha_attn_s", Json::Num(self.alpha_attn_s));
        o.insert("link_bw", Json::Num(self.link_bw));
        o.insert("alpha_comm_s", Json::Num(self.alpha_comm_s));
        o.insert("hbm_bw", Json::Num(self.hbm_bw));
        o.insert("nvlink", Json::Bool(self.nvlink));
        o.insert("multi_node", Json::Bool(self.multi_node));
        Json::Obj(o)
    }
}

const GB: usize = 1 << 30;

/// A DEP partition of a testbed into attention group + expert group
/// (`ag + eg <= n_gpus`, both non-empty).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupSplit {
    pub ag: usize,
    pub eg: usize,
}

impl GroupSplit {
    pub fn new(ag: usize, eg: usize) -> Self {
        assert!(ag >= 1 && eg >= 1, "both groups must be non-empty");
        Self { ag, eg }
    }

    /// The paper's evaluated splits per testbed/model (§5.3, §5.5).
    pub fn paper_default(testbed: &Testbed, has_shared: bool) -> Self {
        if testbed.n_gpus >= 32 {
            Self::new(8, 24)
        } else if has_shared {
            Self::new(3, 5) // DeepSeek-V2 on 8-GPU testbeds
        } else {
            Self::new(4, 4) // Qwen3-MoE on 8-GPU testbeds
        }
    }

    /// All valid splits of a testbed (used by ablation benches).
    pub fn enumerate(n_gpus: usize) -> Vec<Self> {
        (1..n_gpus).map(|ag| Self::new(ag, n_gpus - ag)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn testbeds_have_expected_regimes() {
        let (a, b, c, d) = (Testbed::a(), Testbed::b(), Testbed::c(), Testbed::d());
        // B is the comm-bound regime, C the comm-cheap one.
        assert!(b.link_bw < a.link_bw);
        assert!(c.link_bw > 5.0 * a.link_bw);
        // D crosses nodes: cheaper than C's NVLink, more GPUs.
        assert!(d.link_bw < c.link_bw);
        assert_eq!(d.n_gpus, 32);
        assert!(!b.nvlink && a.nvlink && c.nvlink);
        // HBM streaming (the decode-attention bound) dwarfs the
        // inter-group links everywhere, and the H20 testbeds stream KV
        // far faster than the GDDR cards.
        for t in Testbed::all() {
            assert!(t.hbm_bw > 10.0 * t.link_bw, "{}", t.name);
        }
        assert!(c.hbm_bw > 4.0 * a.hbm_bw);
    }

    #[test]
    fn memory_matches_table2() {
        assert_eq!(Testbed::a().mem_bytes, 48 << 30);
        assert_eq!(Testbed::b().mem_bytes, 24 << 30);
        assert_eq!(Testbed::c().mem_bytes, 96 << 30);
    }

    #[test]
    fn from_profile_swaps_constants_keeps_topology() {
        use crate::perfmodel::profile::CalibrationProfile;
        let base = Testbed::b();
        // A Table-2-equivalent profile reproduces the constants bitwise.
        let same = Testbed::from_profile(&base, &CalibrationProfile::from_testbed(&base));
        for (a, b) in [
            (same.gemm_flops, base.gemm_flops),
            (same.attn_flops, base.attn_flops),
            (same.alpha_comp_s, base.alpha_comp_s),
            (same.alpha_attn_s, base.alpha_attn_s),
            (same.link_bw, base.link_bw),
            (same.alpha_comm_s, base.alpha_comm_s),
            (same.hbm_bw, base.hbm_bw),
        ] {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(same.name.contains("calibrated"));
        // A measured profile moves only the measured constants.
        let mut p = CalibrationProfile::from_testbed(&base);
        p.gemm.unit_per_s = 42e12;
        let cal = Testbed::from_profile(&base, &p);
        assert_eq!(cal.gemm_flops, 42e12);
        assert_eq!(cal.n_gpus, base.n_gpus);
        assert_eq!(cal.mem_bytes, base.mem_bytes);
        assert_eq!(cal.nvlink, base.nvlink);
        assert_eq!(cal.multi_node, base.multi_node);
    }

    #[test]
    fn by_name_case_insensitive() {
        assert_eq!(Testbed::by_name("a").unwrap().n_gpus, 8);
        assert_eq!(Testbed::by_name("D").unwrap().n_gpus, 32);
        assert!(Testbed::by_name("x").is_none());
    }

    #[test]
    fn splits() {
        let s = GroupSplit::paper_default(&Testbed::a(), true);
        assert_eq!((s.ag, s.eg), (3, 5));
        let s = GroupSplit::paper_default(&Testbed::c(), false);
        assert_eq!((s.ag, s.eg), (4, 4));
        let s = GroupSplit::paper_default(&Testbed::d(), true);
        assert_eq!((s.ag, s.eg), (8, 24));
        assert_eq!(GroupSplit::enumerate(8).len(), 7);
    }

    #[test]
    #[should_panic]
    fn empty_group_rejected() {
        GroupSplit::new(0, 8);
    }
}
