//! Warm-start / anytime solver gates (§4 + §5.5 re-solve path):
//!
//! 1. **Warm re-solves** — seeding Algorithm 1 with a previously
//!    solved plan returns the bit-identical winner with strictly fewer
//!    candidate evaluations on every paper instance, and strictly
//!    lower wall time (full mode; quick mode runs too few reps to gate
//!    on timing).
//! 2. **Bound pruning** — `prune: true` matches the `prune: false`
//!    oracle bit for bit everywhere, never costs an extra evaluation,
//!    saves evaluations in aggregate, and fires the §4.2 row bound at
//!    least once across the suite.
//! 3. **Anytime + refinement** — a zero-budget solve still returns a
//!    usable incumbent (flagged non-exhaustive), and the refinement
//!    path (full re-solve warm from the incumbent, published through
//!    the [`PlanCache`] generation token) converges to the unbudgeted
//!    plan bit for bit.
//!
//! Caps run at (m_a ≤ 8, r1 ≤ 8, r2 ≤ 64): the paper-default caps
//! leave several instances with a single Pareto row, where the row
//! bound has nothing to prune.
//!
//! Emits `BENCH_warmsolve.json`. Run: `cargo bench --bench warm_solver`

use std::sync::Arc;
use std::time::{Duration, Instant};

use findep::config::{GroupSplit, ModelConfig, Testbed};
use findep::solver::{
    solve, solve_warm, EvalMode, Instance, PlanCache, ShapeKey, SolverParams, WarmStart,
};
use findep::util::bench::Table;
use findep::util::json::{to_string_pretty, Json, JsonObj};

fn paper_instances() -> Vec<(String, Instance)> {
    let mut out = Vec::new();
    for tb in Testbed::all() {
        for (deepseek, name) in [(true, "deepseek"), (false, "qwen")] {
            let layers = ModelConfig::paper_layers(deepseek, &tb.name[..2]);
            let model = if deepseek {
                ModelConfig::deepseek_v2(layers)
            } else {
                ModelConfig::qwen3_moe(layers)
            };
            let split = GroupSplit::paper_default(&tb, deepseek);
            out.push((
                format!("{name}/{}", tb.name),
                Instance::new(model, tb.clone(), split, 4096),
            ));
        }
    }
    out
}

/// Minimum wall time of `f` over `reps` runs (min, not mean: the
/// comparison is between deterministic workloads, and min is the
/// noise-robust statistic for "this code path does less work").
fn min_wall(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    let quick = std::env::var("FINDEP_BENCH_QUICK").is_ok();
    let reps = if quick { 3 } else { 25 };
    let params = SolverParams { ma_cap: 8, r1_cap: 8, r2_cap: 64, ..Default::default() };
    let mut report = JsonObj::new();

    // --- Gate 1: warm re-solve = cold answer, strictly cheaper. -----
    let mut table = Table::new(
        "warm re-solve vs cold solve (bit-identical winner required)",
        &["instance", "cold evals", "warm evals", "cold wall", "warm wall", "speedup"],
    );
    let mut g1 = Vec::new();
    for (label, inst) in paper_instances() {
        let Some(cold) = solve(&inst, &params) else { continue };
        let seed = WarmStart::from_solution(&cold);
        let warm = solve_warm(&inst, &params, EvalMode::Buffered, &mut inst.evaluator(), Some(&seed))
            .expect("warm re-solve of a feasible instance");
        assert_eq!(warm.config, cold.config, "warm winner drifted on {label}");
        assert_eq!(
            warm.throughput_tokens.to_bits(),
            cold.throughput_tokens.to_bits(),
            "warm throughput drifted on {label}"
        );
        assert_eq!(
            warm.makespan.to_bits(),
            cold.makespan.to_bits(),
            "warm makespan drifted on {label}"
        );
        assert!(warm.warm_seeded && warm.exhaustive);
        assert!(
            warm.evals < cold.evals,
            "warm re-solve must evaluate strictly fewer candidates on {label} \
             (warm {} vs cold {})",
            warm.evals,
            cold.evals
        );
        let t_cold = min_wall(reps, || {
            let _ = solve(&inst, &params);
        });
        let t_warm = min_wall(reps, || {
            let _ =
                solve_warm(&inst, &params, EvalMode::Buffered, &mut inst.evaluator(), Some(&seed));
        });
        if !quick {
            assert!(
                t_warm < t_cold,
                "warm re-solve wall time must beat cold on {label} \
                 ({t_warm:.6}s vs {t_cold:.6}s)"
            );
        }
        table.row(&[
            label.clone(),
            cold.evals.to_string(),
            warm.evals.to_string(),
            format!("{:.1} us", t_cold * 1e6),
            format!("{:.1} us", t_warm * 1e6),
            format!("{:.2}x", t_cold / t_warm),
        ]);
        let mut j = JsonObj::new();
        j.insert("instance", Json::Str(label));
        j.insert("cold_evals", Json::Num(cold.evals as f64));
        j.insert("warm_evals", Json::Num(warm.evals as f64));
        j.insert("cold_wall_s", Json::Num(t_cold));
        j.insert("warm_wall_s", Json::Num(t_warm));
        j.insert("bit_identical", Json::Bool(true));
        g1.push(Json::Obj(j));
    }
    table.print();
    report.insert("warm_vs_cold", Json::Arr(g1));

    // --- Gate 2: pruning = oracle answer, fewer evals, bound fires. -
    let oracle_params = SolverParams { prune: false, ..params };
    let mut table = Table::new(
        "bound pruning vs prune-off oracle (bit-identical winner required)",
        &["instance", "oracle evals", "pruned evals", "rows bound-pruned"],
    );
    let (mut sum_oracle, mut sum_pruned, mut total_rows_pruned) = (0usize, 0usize, 0usize);
    let mut g2 = Vec::new();
    for (label, inst) in paper_instances() {
        let Some(o) = solve(&inst, &oracle_params) else { continue };
        let p = solve(&inst, &params).expect("prune on/off agree on feasibility");
        assert_eq!(p.config, o.config, "pruned winner drifted on {label}");
        assert_eq!(
            p.throughput_tokens.to_bits(),
            o.throughput_tokens.to_bits(),
            "pruned throughput drifted on {label}"
        );
        assert_eq!(o.pruned_rows, 0, "oracle must not prune on {label}");
        assert!(
            p.evals <= o.evals,
            "pruning may never cost evaluations on {label} (pruned {} vs oracle {})",
            p.evals,
            o.evals
        );
        sum_oracle += o.evals;
        sum_pruned += p.evals;
        total_rows_pruned += p.pruned_rows;
        table.row(&[
            label.clone(),
            o.evals.to_string(),
            p.evals.to_string(),
            p.pruned_rows.to_string(),
        ]);
        let mut j = JsonObj::new();
        j.insert("instance", Json::Str(label));
        j.insert("oracle_evals", Json::Num(o.evals as f64));
        j.insert("pruned_evals", Json::Num(p.evals as f64));
        j.insert("rows_pruned", Json::Num(p.pruned_rows as f64));
        g2.push(Json::Obj(j));
    }
    table.print();
    assert!(
        sum_pruned < sum_oracle,
        "pruning must save evaluations in aggregate ({sum_pruned} vs {sum_oracle})"
    );
    assert!(
        total_rows_pruned >= 1,
        "the §4.2 row bound must fire at least once across the paper suite"
    );
    println!(
        "pruning: {sum_oracle} -> {sum_pruned} evaluations across the suite, \
         {total_rows_pruned} rows skipped whole by the bound"
    );
    report.insert("pruning", {
        let mut j = JsonObj::new();
        j.insert("oracle_evals", Json::Num(sum_oracle as f64));
        j.insert("pruned_evals", Json::Num(sum_pruned as f64));
        j.insert("rows_pruned", Json::Num(total_rows_pruned as f64));
        j.insert("per_instance", Json::Arr(g2));
        Json::Obj(j)
    });

    // --- Gate 3: anytime truncation + refinement convergence. -------
    let (label, inst) = paper_instances()
        .into_iter()
        .find(|(l, _)| l.starts_with("qwen/C"))
        .expect("qwen/C paper instance exists");
    let budgeted = SolverParams { budget: Some(Duration::ZERO), ..params };
    let cache = PlanCache::new();
    let key = ShapeKey::prefill(4096, 64);
    let (sol, token) = cache.get_or_solve_refinable(key, || solve(&inst, &budgeted));
    let truncated = sol.expect("a zero-budget solve still returns an incumbent");
    assert!(
        !truncated.exhaustive,
        "zero budget must truncate the multi-row sweep on {label}"
    );
    let full = solve(&inst, &params).expect("feasible");
    assert!(full.exhaustive);
    assert!(
        truncated.throughput_tokens <= full.throughput_tokens,
        "the incumbent can never beat the exhaustive plan"
    );
    // The refinement pass: full re-solve warm from the incumbent.
    let refined = solve_warm(
        &inst,
        &params,
        EvalMode::Buffered,
        &mut inst.evaluator(),
        Some(&WarmStart::from_solution(&truncated)),
    )
    .expect("refinement solve");
    assert!(refined.exhaustive);
    assert_eq!(refined.config, full.config, "refinement must converge to the exhaustive plan");
    assert_eq!(refined.throughput_tokens.to_bits(), full.throughput_tokens.to_bits());
    assert!(
        cache.publish_refined(&token, key, Arc::new(refined.clone())),
        "publish into the untouched generation must be live"
    );
    let live = cache.peek(key).expect("entry present").expect("entry solved");
    assert!(live.exhaustive, "the cache must now serve the refined plan");
    assert_eq!(live.config, full.config);
    println!(
        "anytime on {label}: truncated incumbent {:.0} tok/s ({} evals) -> refined {:.0} tok/s \
         ({} evals), published live",
        truncated.throughput_tokens, truncated.evals, full.throughput_tokens, refined.evals
    );
    report.insert("anytime", {
        let mut j = JsonObj::new();
        j.insert("instance", Json::Str(label));
        j.insert("truncated_tput", Json::Num(truncated.throughput_tokens));
        j.insert("truncated_evals", Json::Num(truncated.evals as f64));
        j.insert("refined_tput", Json::Num(refined.throughput_tokens));
        j.insert("refined_evals", Json::Num(refined.evals as f64));
        j.insert("converged", Json::Bool(true));
        Json::Obj(j)
    });

    std::fs::write("BENCH_warmsolve.json", to_string_pretty(&Json::Obj(report)))
        .expect("write BENCH_warmsolve.json");
    println!("\nwrote BENCH_warmsolve.json");
}
