//! Deterministic fault-injection plane for the serving stack.
//!
//! A [`FaultPlan`] is a pure schedule: given a replica id and the
//! ordinal of the batch it is about to serve, it answers "what goes
//! wrong here?" ([`FaultAction`]). The plan owns no clocks and no
//! state — per-replica batch ordinals are counted by the pool that
//! consults it — so the same plan replayed over the same request
//! stream produces the same fault sequence, which is what lets the
//! resilience property tests and the chaos bench assert exact
//! delivery invariants under failure.
//!
//! Plans come from three places: the hand-built [`FaultPlan::reference`]
//! instance the chaos bench gates on, seeded [`FaultPlan::random`]
//! schedules (SplitMix64, [`crate::util::rng::Rng`]) for property
//! tests, and [`FaultPlan::parse`] for the `serve --fault-plan` CLI
//! spec. Injection happens at the `ReplicaLease` boundary in
//! [`crate::coordinator::server`]; `Server` itself never sees a fault.

use crate::util::rng::Rng;

/// What a faulty replica does, starting at some batch ordinal.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Every serve from `from_batch` on fails.
    Permanent,
    /// The next `count` serves from `from_batch` on fail, then the
    /// replica recovers.
    Transient { count: u64 },
    /// Every serve from `from_batch` on takes `factor`× as long.
    Slow { factor: f64 },
    /// The worker thread panics at exactly `from_batch` (one-shot).
    Panic,
}

/// One scheduled fault on one replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Pool replica id the fault applies to.
    pub replica: usize,
    /// Per-replica served-batch ordinal (0-based) at which it starts.
    pub from_batch: u64,
    pub kind: FaultKind,
}

/// What the lease boundary should do for one (replica, ordinal) serve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    /// No fault: serve normally.
    None,
    /// Fail the serve with an injected error (replica never runs it).
    Fail,
    /// Panic the worker thread mid-serve.
    Panic,
    /// Serve normally, then inflate the observed latency by this factor.
    Slow(f64),
}

/// A deterministic schedule of replica faults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new(specs: Vec<FaultSpec>) -> Self {
        Self { specs }
    }

    /// True when the plan injects nothing (the inert default).
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// The chaos-bench reference plan: out of `n_replicas`, replica 1
    /// fails permanently, replica 2 fails transiently for 3 batches,
    /// replica 3 runs 2× slow; replica 0 (and any beyond 3) stay
    /// healthy. Degrades gracefully when the pool is smaller.
    pub fn reference(n_replicas: usize) -> Self {
        let mut specs = Vec::new();
        if n_replicas > 1 {
            specs.push(FaultSpec { replica: 1, from_batch: 1, kind: FaultKind::Permanent });
        }
        if n_replicas > 2 {
            specs.push(FaultSpec {
                replica: 2,
                from_batch: 2,
                kind: FaultKind::Transient { count: 3 },
            });
        }
        if n_replicas > 3 {
            specs.push(FaultSpec {
                replica: 3,
                from_batch: 0,
                kind: FaultKind::Slow { factor: 2.0 },
            });
        }
        Self { specs }
    }

    /// Seeded random schedule for property tests. Always leaves at
    /// least one replica fault-free so forward progress is possible,
    /// and never draws `Panic` (panic schedules are exercised by
    /// dedicated tests, not the randomized exactly-once sweep).
    pub fn random(seed: u64, n_replicas: usize) -> Self {
        let mut rng = Rng::new(seed);
        if n_replicas < 2 {
            return Self::default();
        }
        let mut ids: Vec<usize> = (0..n_replicas).collect();
        rng.shuffle(&mut ids);
        let n_faulty = rng.range_u64(1, (n_replicas - 1) as u64) as usize;
        let mut specs = Vec::with_capacity(n_faulty);
        for &replica in ids.iter().take(n_faulty) {
            let from_batch = rng.below(6);
            let kind = match rng.below(3) {
                0 => FaultKind::Permanent,
                1 => FaultKind::Transient { count: rng.range_u64(1, 4) },
                _ => FaultKind::Slow { factor: rng.range_f64(1.5, 3.0) },
            };
            specs.push(FaultSpec { replica, from_batch, kind });
        }
        Self { specs }
    }

    /// Parse a CLI plan spec against a pool of `n_replicas`.
    ///
    /// Whole-plan forms: `reference`, `random:<seed>`. Otherwise a
    /// comma-separated list of `<replica>=<kind>` entries where kind is
    /// `perm`, `fail:<count>`, `slow:<factor>`, or `panic`, each with
    /// an optional `@<batch>` start ordinal (default 0) — e.g.
    /// `1=perm,2=fail:3@2,3=slow:2.0`.
    pub fn parse(spec: &str, n_replicas: usize) -> Result<Self, String> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(Self::default());
        }
        if spec == "reference" {
            return Ok(Self::reference(n_replicas));
        }
        if let Some(seed) = spec.strip_prefix("random:") {
            let seed: u64 =
                seed.parse().map_err(|_| format!("bad seed in fault plan `{spec}`"))?;
            return Ok(Self::random(seed, n_replicas));
        }
        let mut specs = Vec::new();
        for entry in spec.split(',') {
            let entry = entry.trim();
            let (replica, kind_str) = entry
                .split_once('=')
                .ok_or_else(|| format!("fault entry `{entry}` is not `<replica>=<kind>`"))?;
            let replica: usize = replica
                .trim()
                .parse()
                .map_err(|_| format!("bad replica id in fault entry `{entry}`"))?;
            if replica >= n_replicas {
                return Err(format!(
                    "fault entry `{entry}` names replica {replica} but the pool has {n_replicas}"
                ));
            }
            let (kind_str, from_batch) = match kind_str.split_once('@') {
                Some((k, at)) => (
                    k,
                    at.parse::<u64>()
                        .map_err(|_| format!("bad `@<batch>` ordinal in fault entry `{entry}`"))?,
                ),
                None => (kind_str, 0),
            };
            let kind = if kind_str == "perm" {
                FaultKind::Permanent
            } else if kind_str == "panic" {
                FaultKind::Panic
            } else if let Some(count) = kind_str.strip_prefix("fail:") {
                let count: u64 = count
                    .parse()
                    .map_err(|_| format!("bad count in fault entry `{entry}`"))?;
                if count == 0 {
                    return Err(format!("fault entry `{entry}` has zero count"));
                }
                FaultKind::Transient { count }
            } else if let Some(factor) = kind_str.strip_prefix("slow:") {
                let factor: f64 = factor
                    .parse()
                    .map_err(|_| format!("bad factor in fault entry `{entry}`"))?;
                if factor < 1.0 || factor.is_nan() {
                    return Err(format!("fault entry `{entry}` has slow factor < 1"));
                }
                FaultKind::Slow { factor }
            } else {
                return Err(format!(
                    "unknown fault kind `{kind_str}` (want perm | fail:<n> | slow:<f> | panic)"
                ));
            };
            specs.push(FaultSpec { replica, from_batch, kind });
        }
        Ok(Self { specs })
    }

    /// The action for `replica` serving its `ordinal`-th batch
    /// (0-based, counted per replica by the pool). When several specs
    /// apply, the strongest wins: Panic > Fail > Slow.
    pub fn action(&self, replica: usize, ordinal: u64) -> FaultAction {
        let mut action = FaultAction::None;
        for s in &self.specs {
            if s.replica != replica {
                continue;
            }
            let hit = match s.kind {
                FaultKind::Permanent => ordinal >= s.from_batch,
                FaultKind::Transient { count } => {
                    ordinal >= s.from_batch && ordinal < s.from_batch.saturating_add(count)
                }
                FaultKind::Slow { factor: _ } => ordinal >= s.from_batch,
                FaultKind::Panic => ordinal == s.from_batch,
            };
            if !hit {
                continue;
            }
            let this = match s.kind {
                FaultKind::Panic => FaultAction::Panic,
                FaultKind::Permanent | FaultKind::Transient { .. } => FaultAction::Fail,
                FaultKind::Slow { factor } => FaultAction::Slow(factor),
            };
            action = match (action, this) {
                (FaultAction::Panic, _) | (_, FaultAction::Panic) => FaultAction::Panic,
                (FaultAction::Fail, _) | (_, FaultAction::Fail) => FaultAction::Fail,
                (FaultAction::Slow(f), _) => FaultAction::Slow(f),
                (FaultAction::None, other) => other,
            };
        }
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_inert() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        for r in 0..4 {
            for b in 0..10 {
                assert_eq!(p.action(r, b), FaultAction::None);
            }
        }
    }

    #[test]
    fn permanent_fails_from_start_ordinal_forever() {
        let p = FaultPlan::new(vec![FaultSpec {
            replica: 1,
            from_batch: 2,
            kind: FaultKind::Permanent,
        }]);
        assert_eq!(p.action(1, 0), FaultAction::None);
        assert_eq!(p.action(1, 1), FaultAction::None);
        assert_eq!(p.action(1, 2), FaultAction::Fail);
        assert_eq!(p.action(1, 1000), FaultAction::Fail);
        assert_eq!(p.action(0, 2), FaultAction::None, "other replicas untouched");
    }

    #[test]
    fn transient_recovers_after_count() {
        let p = FaultPlan::new(vec![FaultSpec {
            replica: 0,
            from_batch: 1,
            kind: FaultKind::Transient { count: 2 },
        }]);
        assert_eq!(p.action(0, 0), FaultAction::None);
        assert_eq!(p.action(0, 1), FaultAction::Fail);
        assert_eq!(p.action(0, 2), FaultAction::Fail);
        assert_eq!(p.action(0, 3), FaultAction::None);
    }

    #[test]
    fn panic_is_one_shot_and_strongest() {
        let p = FaultPlan::new(vec![
            FaultSpec { replica: 2, from_batch: 3, kind: FaultKind::Panic },
            FaultSpec { replica: 2, from_batch: 0, kind: FaultKind::Slow { factor: 2.0 } },
        ]);
        assert_eq!(p.action(2, 2), FaultAction::Slow(2.0));
        assert_eq!(p.action(2, 3), FaultAction::Panic);
        assert_eq!(p.action(2, 4), FaultAction::Slow(2.0));
    }

    #[test]
    fn fail_outranks_slow() {
        let p = FaultPlan::new(vec![
            FaultSpec { replica: 0, from_batch: 0, kind: FaultKind::Slow { factor: 3.0 } },
            FaultSpec { replica: 0, from_batch: 1, kind: FaultKind::Transient { count: 1 } },
        ]);
        assert_eq!(p.action(0, 0), FaultAction::Slow(3.0));
        assert_eq!(p.action(0, 1), FaultAction::Fail);
        assert_eq!(p.action(0, 2), FaultAction::Slow(3.0));
    }

    #[test]
    fn reference_plan_shape() {
        let p = FaultPlan::reference(4);
        assert_eq!(p.specs().len(), 3);
        assert_eq!(p.action(0, 100), FaultAction::None, "replica 0 stays healthy");
        assert_eq!(p.action(1, 1), FaultAction::Fail);
        assert_eq!(p.action(2, 2), FaultAction::Fail);
        assert_eq!(p.action(2, 5), FaultAction::None, "transient recovers");
        assert_eq!(p.action(3, 0), FaultAction::Slow(2.0));
        // Smaller pools drop the specs that don't fit.
        assert!(FaultPlan::reference(1).is_empty());
        assert_eq!(FaultPlan::reference(2).specs().len(), 1);
    }

    #[test]
    fn random_is_deterministic_and_leaves_a_healthy_replica() {
        for seed in 0..50 {
            let a = FaultPlan::random(seed, 4);
            let b = FaultPlan::random(seed, 4);
            assert_eq!(a, b, "seed {seed} not deterministic");
            assert!(!a.is_empty());
            assert!(a.specs().len() <= 3, "seed {seed} faulted every replica");
            let faulted: std::collections::BTreeSet<usize> =
                a.specs().iter().map(|s| s.replica).collect();
            assert!(faulted.len() < 4, "seed {seed} left no healthy replica");
            assert!(
                a.specs().iter().all(|s| s.kind != FaultKind::Panic),
                "random plans never draw Panic"
            );
        }
        assert_ne!(FaultPlan::random(1, 4), FaultPlan::random(2, 4));
    }

    #[test]
    fn parse_entries() {
        let p = FaultPlan::parse("1=perm,2=fail:3@2, 3=slow:2.5", 4).unwrap();
        assert_eq!(
            p.specs(),
            &[
                FaultSpec { replica: 1, from_batch: 0, kind: FaultKind::Permanent },
                FaultSpec { replica: 2, from_batch: 2, kind: FaultKind::Transient { count: 3 } },
                FaultSpec { replica: 3, from_batch: 0, kind: FaultKind::Slow { factor: 2.5 } },
            ]
        );
        let p = FaultPlan::parse("0=panic@5", 1).unwrap();
        assert_eq!(p.action(0, 5), FaultAction::Panic);
    }

    #[test]
    fn parse_whole_plan_forms() {
        assert_eq!(FaultPlan::parse("reference", 4).unwrap(), FaultPlan::reference(4));
        assert_eq!(FaultPlan::parse("random:9", 4).unwrap(), FaultPlan::random(9, 4));
        assert!(FaultPlan::parse("", 4).unwrap().is_empty());
        assert!(FaultPlan::parse("none", 4).unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("9=perm", 4).is_err(), "replica out of range");
        assert!(FaultPlan::parse("1", 4).is_err(), "missing kind");
        assert!(FaultPlan::parse("1=wat", 4).is_err(), "unknown kind");
        assert!(FaultPlan::parse("1=fail:0", 4).is_err(), "zero count");
        assert!(FaultPlan::parse("1=slow:0.5", 4).is_err(), "shrinking factor");
        assert!(FaultPlan::parse("1=perm@x", 4).is_err(), "bad ordinal");
        assert!(FaultPlan::parse("random:x", 4).is_err(), "bad seed");
    }
}
